"""repro: sparsity-driven gradient-synchronization reproduction.

Importing this package enables ``jax_threefry_partitionable``.  The TP
mesh-invariance contract (DESIGN.md §9) requires ``jax.random`` bits to be
a pure function of (key, shape) regardless of how the result — or the
computation producing it — is sharded.  The legacy (non-partitionable)
threefry lowering does not guarantee that: a ``[rows, d]`` normal draw
materialized under a ``P('model', None)`` out-sharding produces different
bits on a (2, 4) mesh than on (1, 1), which made parameter initialization
mesh-dependent and broke cross-mesh loss parity for every sync scheme
(dense included).  Newer jax releases default the flag on; pinning it here
makes the pinned CI leg (jax 0.4.x) behave identically to latest.
"""
import jax

try:
    jax.config.update("jax_threefry_partitionable", True)
except Exception:  # pragma: no cover — flag retired once always-on upstream
    pass

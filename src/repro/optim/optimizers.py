"""Optimizers operating on flat f32 chunks (ZeRO-1 friendly).

The trainer flattens every param leaf, pads to a multiple of the
data-parallel world, and hands each rank its chunk; these update rules are
shape-agnostic so they work on full leaves (smoke tests) and chunks (ZeRO-1)
alike.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"      # adamw | sgd
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0   # global-norm clip (0 = off)


def adamw_init(p: jnp.ndarray) -> dict:
    return {
        "m": jnp.zeros(p.shape, jnp.float32),
        "v": jnp.zeros(p.shape, jnp.float32),
    }


def adamw_update(cfg: OptConfig, p, g, st, step):
    g = g.astype(jnp.float32)
    pf = p.astype(jnp.float32)
    m = cfg.b1 * st["m"] + (1 - cfg.b1) * g
    v = cfg.b2 * st["v"] + (1 - cfg.b2) * g * g
    t = step.astype(jnp.float32) + 1.0
    mh = m / (1 - cfg.b1 ** t)
    vh = v / (1 - cfg.b2 ** t)
    upd = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * pf
    return (pf - cfg.lr * upd).astype(p.dtype), {"m": m, "v": v}


def sgd_init(p: jnp.ndarray) -> dict:
    return {"mom": jnp.zeros(p.shape, jnp.float32)}


def sgd_update(cfg: OptConfig, p, g, st, step):
    del step
    mom = 0.9 * st["mom"] + g.astype(jnp.float32)
    return (p.astype(jnp.float32) - cfg.lr * mom).astype(p.dtype), {"mom": mom}


INITS = {"adamw": adamw_init, "sgd": sgd_init}
UPDATES = {"adamw": adamw_update, "sgd": sgd_update}


def ef_residual_init(struct):
    """Zero error-feedback residual memory from its ShapeDtypeStruct tree.

    The EF residual (core/sparsify.py, DESIGN.md §8) is optimizer state —
    initialized here, checkpointed with the moments, threaded through
    every update — but unlike the moments it is per-device and never
    ZeRO-chunked: compression consumes the *local* bucket payload before
    the ZeRO-1 update partitions anything, so chunking it would hand each
    rank the wrong memory."""
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), struct)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))

from repro.optim.optimizers import (  # noqa: F401
    OptConfig, adamw_init, adamw_update, sgd_init, sgd_update,
)

"""Double-buffered emission of per-bucket sync ops (DESIGN.md §7).

The monolithic trainer synchronized the whole gradient pytree in one
``GradSync`` call — zero overlap between wire time and compute.  This
module emits one sync chain per bucket (`repro.core.buckets.BucketPlan`)
in a software pipeline:

    enc[0] = encode(bucket 0)
    for i in buckets:
        enc[i+1] = encode(bucket i+1)      # local compute
        out[i]   = commit(bucket i, enc[i])  # collective + decode-apply

``encode`` is the bucket's local, collective-free stage (Zen's sparsify +
hierarchical hash + partition extract; identity for dense buckets) and
``commit`` is everything from the first collective on.  Because
``enc[i+1]`` has no data dependency on ``commit(i)``, XLA's latency-hiding
scheduler is free to run bucket *i*'s collective on the wire while bucket
*i+1* encodes — that is the double-buffering contract.  An
``optimization_barrier`` ties ``(enc[i], enc[i+1])`` together before
``commit(i)`` so the compiler can neither hoist every encode to the front
(peak-memory blowup) nor sink a commit past its successor's encode
(serializing the pipeline).  The barrier is the identity on values:
scheduling changes bits never.

With a single bucket (``bucket_bytes=None`` fallback) the loop degenerates
to encode-then-commit per leaf — op-for-op the monolithic path.
"""
from __future__ import annotations

from typing import Any, Callable, Sequence

from jax import lax

from repro.core.buckets import Bucket
from repro.core.schemes import SyncStats


def _fence(tree):
    """``optimization_barrier`` as a value-identity scheduling fence.

    Under ``vmap`` (the single-device scheme simulation used by tests and
    traffic accounting) some jax versions have no batching rule for the
    barrier — there is no scheduler to fence there, so it degrades to the
    identity.  Under jit/shard_map (the trainer) the barrier is real."""
    try:
        return lax.optimization_barrier(tree)
    except NotImplementedError:
        return tree


def run_schedule(
    buckets: Sequence[Bucket],
    payloads: Sequence[Any],
    encode: Callable[[Bucket, Any], Any],
    commit: Callable[[Bucket, Any], tuple[Any, SyncStats]],
    compress: Callable[[Bucket, Any], Any] | None = None,
    intra: Callable[[Bucket, Any], Any] | None = None,
) -> tuple[list[Any], list[SyncStats]]:
    """Emit the double-buffered per-bucket sync pipeline.

    ``compress``, when given, is the error-feedback sparsification stage
    (core/sparsify.py): ``compress(bucket, payload) -> payload'``, applied
    immediately before ``encode`` *inside the same pipeline slot* — so
    bucket i+1 sparsifies AND encodes while bucket i's collective is on
    the wire, and the fence covers the whole compress+encode prefetch.
    Residual-memory updates are the caller's side channel (GradSync keeps
    them per bucket); the schedule only sees the transformed payload.

    ``intra``, when given, is the hierarchical topology's fast-level
    stage (DESIGN.md §10): ``intra(bucket, enc) -> enc'`` runs bucket
    *i*'s intra-node collective between the encode fence and the commit,
    and a second fence ties ``(intra(i), encode(i+1))`` together — so the
    cheap intra hop of bucket *i* hides under bucket *i+1*'s encode
    compute exactly like the slow commit hides under it, instead of
    serializing in front of it.  ``intra=None`` (flat topology) emits
    op-for-op the historical two-stage pipeline.

    Returns (synced payloads, per-bucket SyncStats), both in bucket order.
    """
    nb = len(buckets)
    outs: list[Any] = [None] * nb
    stats: list[SyncStats] = [None] * nb
    if nb == 0:
        return outs, stats

    def prefetch(i: int):
        p = payloads[i]
        if compress is not None:
            p = compress(buckets[i], p)
        return encode(buckets[i], p)

    enc = prefetch(0)
    for i, b in enumerate(buckets):
        nxt = prefetch(i + 1) if i + 1 < nb else None
        if nxt is not None:
            # value-identity fence: bucket i+1's encode must be materialized
            # before bucket i's commit results are consumed (double buffer).
            enc, nxt = _fence((enc, nxt))
        if intra is not None:
            enc = intra(b, enc)
            if nxt is not None:
                # fence the intra stage of bucket i against encode(i+1):
                # the fast hop must not sink past the prefetch it is
                # supposed to overlap with.
                enc, nxt = _fence((enc, nxt))
        outs[i], stats[i] = commit(b, enc)
        enc = nxt
    return outs, stats


def encode_all(
    buckets: Sequence[Bucket],
    payloads: Sequence[Any],
    encode: Callable[[Bucket, Any], Any],
    compress: Callable[[Bucket, Any], Any] | None = None,
) -> list[Any]:
    """The pipeline's local prefix in isolation: compress (optional) +
    encode of every bucket, no collectives, no fences.

    This is what ``run_schedule`` overlaps with wire time — exposed
    separately so the CostCalibrator and the per-stage benchmark split
    (benchmarks/run.py ``stages``) can time encode without a mesh
    (DESIGN.md §11).  Returns the per-bucket encode results in order.
    """
    out = []
    for b, p in zip(buckets, payloads):
        if compress is not None:
            p = compress(b, p)
        out.append(encode(b, p))
    return out

"""Glue: build jitted, mesh-mapped train / serve programs for an arch.

This is the layer the launcher, dry-run, smoke tests, and examples all call.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data.pipeline import make_batch_specs
from repro.models.common import ArchConfig, make_ctx
from repro.models.model import (Model, assert_mesh_invariant_params,
                                build_model)
from repro.train import steps as st
from repro.train.steps import TrainerConfig


def _shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    """jax.shard_map on new jax; jax.experimental.shard_map (check_rep
    spelling) on older releases."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


@dataclasses.dataclass
class Program:
    """A compiled-able distributed program bundle for one architecture."""

    cfg: ArchConfig
    model: Model
    mesh: Mesh
    tcfg: TrainerConfig
    param_shapes: Any
    param_specs: Any
    # jitted entry points (built lazily per mode)
    train_step: Any = None
    prefill_step: Any = None
    decode_step: Any = None
    batch_specs: Any = None
    cache_specs: Any = None
    # the trainer's GradSync (set by attach_train): owns the bucket plan,
    # compressor tags, and the EF-residual shape contract that the
    # optimizer state must match (DESIGN.md §8)
    gradsync: Any = None
    # measured sparsity profiles used at the last (re)plan — the
    # DensityController feedback loop writes here via attach_train
    sparsity_profiles: Any = None

    def init_params(self, seed: int = 0):
        shardings = jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), self.param_specs,
            is_leaf=lambda x: isinstance(x, P))
        fn = jax.jit(lambda k: self.model.init(k)[0],
                     out_shardings=shardings)
        return fn(jax.random.PRNGKey(seed))

    def fresh_cache(self):
        """A correctly-initialized global decode cache (zeros, pos = -1,
        t = 0).  Requires attach_serve(..., mode='decode') first."""
        shapes = self.cache_specs["global_shapes"]

        def leaf(path, s):
            name = str(getattr(path[-1], "key", ""))
            if name == "pos":
                return jnp.full(s.shape, -1, s.dtype)
            return jnp.zeros(s.shape, s.dtype)

        return jax.tree_util.tree_map_with_path(leaf, shapes)

    def init_opt(self, params):
        if self.gradsync is None and self.tcfg.sync.compress != "none":
            raise ValueError(
                "EF compression sizes the residual from the bucket plan: "
                "call attach_train(prog, ...) before init_opt")
        ospecs = st.opt_pspecs(self.tcfg, self.param_specs, self.model.ctx,
                               gradsync=self.gradsync)
        shardings = jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), ospecs,
            is_leaf=lambda x: isinstance(x, P))
        fn = jax.jit(functools.partial(st.init_opt_state, self.tcfg,
                                       ctx=self.model.ctx,
                                       param_specs=self.param_specs,
                                       gradsync=self.gradsync),
                     out_shardings=shardings)
        return fn(params)


def build_program(cfg: ArchConfig, mesh: Mesh,
                  tcfg: TrainerConfig | None = None,
                  pad_heads: bool = False,
                  moe_a2a: bool = False) -> Program:
    from repro.core.topology import DP_INTER, DP_INTRA

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = sizes.get("model", 1)
    pods = sizes.get("pod", 1)
    # a node-split mesh (launch/mesh.py --node-size) carries the data
    # parallelism as nested (dp_inter, dp_intra) axes; the ctx keeps dp as
    # the TOTAL data degree and records the node grouping separately
    node_size = sizes.get(DP_INTRA, 1)
    dp = sizes.get("data", 1) * sizes.get(DP_INTER, 1) * node_size
    ctx = make_ctx(cfg, tp, dp, pods, pad_heads=pad_heads, moe_a2a=moe_a2a,
                   node_size=node_size)
    model = build_model(cfg, ctx)
    shapes, specs = model.abstract()
    # hard contract (DESIGN.md §9): the global param pytree must not depend
    # on the mesh — cheap (abstract-only) and runs on every build
    assert_mesh_invariant_params(cfg, ctx, shapes)
    return Program(cfg=cfg, model=model, mesh=mesh,
                   tcfg=tcfg or TrainerConfig(),
                   param_shapes=shapes, param_specs=specs)


def attach_train(prog: Program, seq_len: int, global_batch: int,
                 sparsity_profiles=None) -> None:
    """Build prog.train_step: (params, opt_state, batch) -> (params, opt,
    metrics).

    ``sparsity_profiles`` ({bucket-key/leaf-path: SparsityProfile}) feeds
    measured density curves into the per-bucket 'auto' scheme choice —
    the DensityController replan path re-calls attach_train with the
    profiles it has learned (bucket boundaries and residual shapes are
    profile-independent, so existing params/opt_state stay valid)."""
    model, mesh, tcfg = prog.model, prog.mesh, prog.tcfg
    ctx = model.ctx
    n_shards = ctx.dp * (ctx.pods if ctx.pod_axis else 1)
    bshapes = make_batch_specs(prog.cfg, seq_len, global_batch, "train")
    bspecs = st.batch_pspecs(bshapes, ctx, n_shards)
    prog.sparsity_profiles = sparsity_profiles
    prog.gradsync = st.make_gradsync(model, tcfg, prog.param_specs,
                                     prog.param_shapes, sparsity_profiles)
    ospecs = st.opt_pspecs(tcfg, prog.param_specs, ctx,
                           gradsync=prog.gradsync)
    step_fn = st.make_train_step(model, tcfg, prog.param_specs,
                                 gradsync=prog.gradsync)
    metric_specs = P()
    mapped = _shard_map(
        step_fn, mesh=mesh,
        in_specs=(prog.param_specs, ospecs, bspecs),
        out_specs=(prog.param_specs, ospecs, metric_specs),
        check_vma=False)
    prog.train_step = jax.jit(mapped, donate_argnums=(0, 1))
    prog.batch_specs = {"shapes": bshapes, "pspecs": bspecs}


def attach_serve(prog: Program, seq_len: int, global_batch: int,
                 mode: str) -> None:
    """Build prog.prefill_step / prog.decode_step for an input shape."""
    model, mesh = prog.model, prog.mesh
    cfg, ctx = prog.cfg, model.ctx
    n_shards = ctx.dp * (ctx.pods if ctx.pod_axis else 1)
    window = cfg.sliding_window if seq_len > 65536 else 0
    cache_len = min(seq_len, window) if window else seq_len

    if mode == "prefill":
        bshapes = make_batch_specs(cfg, seq_len, global_batch, "prefill")
        bspecs = st.batch_pspecs(bshapes, ctx, n_shards)
        cspecs = st.cache_pspecs(model)
        fn = st.make_prefill_step(model)
        mapped = _shard_map(
            fn, mesh=mesh, in_specs=(prog.param_specs, bspecs),
            out_specs=(P(bspecs["tokens"][0], "model"), cspecs),
            check_vma=False)
        prog.prefill_step = jax.jit(mapped)
        prog.batch_specs = {"shapes": bshapes, "pspecs": bspecs}
        prog.cache_specs = cspecs
        return

    # decode
    bshapes = make_batch_specs(cfg, seq_len, global_batch, "decode")
    bspecs = st.batch_pspecs(bshapes, ctx, n_shards)
    cspecs = st.cache_pspecs(model)
    batch_local = (global_batch // n_shards
                   if global_batch % n_shards == 0 and n_shards > 1
                   else global_batch)
    local_cache = model.make_cache(batch_local, cache_len, abstract=True)
    local_cache["t"] = jax.ShapeDtypeStruct((), jnp.int32)
    global_cache = st.globalize_cache(local_cache, cspecs, mesh)
    fn = st.make_decode_step(model, window=window)
    tok_spec = bspecs["tokens"]
    mapped = _shard_map(
        fn, mesh=mesh,
        in_specs=(prog.param_specs, cspecs, tok_spec),
        out_specs=(tok_spec, P(tok_spec[0]), cspecs),
        check_vma=False)
    prog.decode_step = jax.jit(mapped, donate_argnums=(1,))
    prog.batch_specs = {"shapes": bshapes, "pspecs": bspecs}
    prog.cache_specs = {"pspecs": cspecs, "global_shapes": global_cache,
                        "local_shapes": local_cache, "window": window,
                        "cache_len": cache_len}

from repro.train.steps import TrainerConfig  # noqa: F401
from repro.train.build import (  # noqa: F401
    Program, build_program, attach_train, attach_serve,
)

"""Distributed train / serve steps (per-device SPMD programs + shard_map
wrappers).

Gradient flow inside one train step:
  1. local grads via ``jax.value_and_grad`` of the per-device loss;
  2. model-replicated leaves (norms, KV projections, router) are psum'd over
     the ``model`` axis (their true gradient sums each rank's path);
  3. ``GradSync`` synchronizes over ``data`` (+ ``pod``) — this step IS the
     paper's subject.  The pytree is partitioned into fixed-byte buckets
     (``repro.core.buckets``): dense leaves fuse into flat psum buckets,
     row-sparse tables stay whole and get a per-tensor scheme (Zen or a
     baseline; 'auto' decides leaf-by-leaf from the cost model).  Bucket
     sync ops are emitted double-buffered (``repro.train.schedule``) so
     XLA's latency-hiding scheduler can overlap bucket *i*'s collective
     with bucket *i+1*'s encode.  ``SyncConfig.bucket_bytes=None`` keeps
     the monolithic per-leaf path bit-exactly;
  4. ZeRO-1 update: each (pod, data) rank updates its flat chunk of every
     leaf and the new params are all-gathered back.

Serve steps (prefill / decode) use the sequence-sharded KV cache layout
from ``repro.models`` (context-parallel decode over ``model``).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.zen import GradSync, SyncConfig
from repro.models.common import ShardCtx
from repro.models.model import Model
from repro.optim.optimizers import INITS, UPDATES, OptConfig, ef_residual_init


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    opt: OptConfig = OptConfig()
    sync: SyncConfig = SyncConfig()
    zero1: bool = True


# ---------------------------------------------------------------------------
# spec utilities
# ---------------------------------------------------------------------------

def _has_model(spec: P) -> bool:
    return any(
        s == "model" or (isinstance(s, tuple) and "model" in s)
        for s in spec if s is not None
    )


def batch_pspecs(batch_shapes: dict, ctx: ShardCtx, n_batch_shards: int) -> dict:
    """Shard dim0 over (pod, data) when divisible, else replicate."""
    out = {}
    for k, v in batch_shapes.items():
        if v.shape and v.shape[0] % n_batch_shards == 0 and n_batch_shards > 1:
            axes = tuple(a for a in ctx.batch_axes)
            out[k] = P(axes if len(axes) > 1 else axes[0],
                       *([None] * (len(v.shape) - 1)))
        else:
            out[k] = P(*([None] * len(v.shape)))
    return out


def zero_axes(ctx: ShardCtx):
    """Mesh axes the ZeRO-1 state shards over: every data-parallel axis
    (plus pod).  Flat: ("data",); node-split: ("dp_inter", "dp_intra") —
    jax collectives take the tuple as one flattened axis, so the ZeRO
    math is topology-agnostic."""
    head = (ctx.pod_axis,) if ctx.pod_axis else ()
    return head + ctx.dp_axes


def _zero_world(ctx: ShardCtx) -> int:
    return ctx.dp * (ctx.pods if ctx.pod_axis else 1)


# ---------------------------------------------------------------------------
# ZeRO-1 optimizer state
# ---------------------------------------------------------------------------

def opt_chunk_size(local_size: int, world: int) -> int:
    return -(-local_size // world)


def _shard_divisor(spec: P, ctx: ShardCtx) -> int:
    div = 1
    sizes = ctx.axis_sizes
    for ax in spec:
        if ax is None:
            continue
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            div *= sizes.get(a, 1)
    return div


def _device_world(ctx: ShardCtx) -> int:
    """Total devices in the mesh — the EF residual is fully per-device
    (each (pod, data, model) rank keeps its own compressed-bucket
    memory), so its global dim0 is the whole device count."""
    return ctx.dp * ctx.tp * (ctx.pods if ctx.pod_axis else 1)


def residual_axes(ctx: ShardCtx) -> tuple:
    """Mesh axes, in mesh order, that shard the residual's dim0."""
    head = (ctx.pod_axis,) if ctx.pod_axis else ()
    return head + ctx.dp_axes + (ctx.tp_axis,)


def init_opt_state(tcfg: TrainerConfig, params, ctx: ShardCtx, param_specs,
                   gradsync=None):
    """Global optimizer state.  ZeRO-1: per-leaf moments shaped
    [world, chunk] where chunk covers the LOCAL (per-device) param shard
    (dim0 sharded over the zero axes).  When ``gradsync`` compresses with
    error feedback, a ``residual`` entry carries one zero f32 vector per
    compressed bucket and device (DESIGN.md §8)."""
    world = _zero_world(ctx)
    init = INITS[tcfg.opt.kind]

    def leaf(p, spec):
        if not tcfg.zero1:
            return init(p)
        local = p.size // _shard_divisor(spec, ctx)
        c = opt_chunk_size(local, world)
        return init(jnp.zeros((world, c), jnp.float32))

    state = jax.tree.map(leaf, params, param_specs)
    out = {"leaves": state, "step": jnp.zeros((), jnp.int32)}
    res = _residual_struct(gradsync, ctx)
    if res is not None:
        out["residual"] = ef_residual_init(res)
    return out


def opt_pspecs(tcfg: TrainerConfig, param_specs, ctx: ShardCtx,
               gradsync=None):
    zaxes = zero_axes(ctx)

    def leaf(spec: P):
        moment_spec = (P(zaxes, None) if tcfg.zero1 else spec)
        return {k: moment_spec for k in INITS[tcfg.opt.kind](
            jnp.zeros((1,), jnp.float32))}

    leaves = jax.tree.map(leaf, param_specs,
                          is_leaf=lambda x: isinstance(x, P))
    out = {"leaves": leaves, "step": P()}
    res = _residual_struct(gradsync, ctx)
    if res is not None:
        out["residual"] = {k: P(residual_axes(ctx)) for k in res}
    return out


def abstract_opt_state(tcfg: TrainerConfig, param_shapes, ctx: ShardCtx,
                       param_specs, gradsync=None):
    world = _zero_world(ctx)
    names = list(INITS[tcfg.opt.kind](jnp.zeros((1,), jnp.float32)))

    def leaf(p, spec):
        if tcfg.zero1:
            local = int(np.prod(p.shape)) // _shard_divisor(spec, ctx)
            c = opt_chunk_size(local, world)
            return {k: jax.ShapeDtypeStruct((world, c), jnp.float32)
                    for k in names}
        return {k: jax.ShapeDtypeStruct(p.shape, jnp.float32) for k in names}

    out = {"leaves": jax.tree.map(leaf, param_shapes, param_specs),
           "step": jax.ShapeDtypeStruct((), jnp.int32)}
    res = _residual_struct(gradsync, ctx)
    if res is not None:
        out["residual"] = res
    return out


def _residual_struct(gradsync, ctx: ShardCtx):
    """Global ShapeDtypeStructs of the EF residual state, or None when the
    sync config keeps no residual (no compression, or ``:noef``)."""
    if gradsync is None or not gradsync.has_compression:
        return None
    sizes = {k: v.shape[0] for k, v in gradsync.init_residual().items()}
    if not sizes:
        return None
    n_dev = _device_world(ctx)
    return {k: jax.ShapeDtypeStruct((n_dev * s,), jnp.float32)
            for k, s in sizes.items()}


# ---------------------------------------------------------------------------
# the per-device train step
# ---------------------------------------------------------------------------

def local_param_shapes(param_shapes, param_specs, ctx: ShardCtx):
    """Global ShapeDtypeStructs -> per-device (shard_map-local) shapes."""
    sizes = ctx.axis_sizes

    def leaf(sds, spec):
        shape = list(sds.shape)
        for i, ax in enumerate(spec):
            if ax is None:
                continue
            axs = ax if isinstance(ax, tuple) else (ax,)
            div = 1
            for a in axs:
                div *= sizes.get(a, 1)
            shape[i] = shape[i] // div
        return jax.ShapeDtypeStruct(tuple(shape), sds.dtype)

    return jax.tree.map(leaf, param_shapes, param_specs,
                        is_leaf=lambda x: isinstance(x, P))


def make_gradsync(model: Model, tcfg: TrainerConfig, param_specs,
                  param_shapes=None, sparsity_profiles=None) -> GradSync:
    """Build the trainer's GradSync OFFLINE (hash layouts, bucket plan,
    compressor tags) from the local (per-device) grad shapes — grads
    match param shards inside shard_map.  The data-parallel Topology
    comes from the ctx's node grouping (``--node-size``) with the sync
    config's α-β override; node_size == 1 builds the degenerate flat
    topology (bit-identical to the pre-topology trainer)."""
    from repro.core.topology import build_topology

    ctx = model.ctx
    if param_shapes is None:
        param_shapes = model.abstract()[0]
    grad_shapes = local_param_shapes(param_shapes, param_specs, ctx)
    topo = build_topology(ctx.dp, ctx.node_size, axis=ctx.dp_axis,
                          alpha_beta=tcfg.sync.alpha_beta)
    return GradSync(
        tcfg.sync, list(model.sparse_paths), grad_shapes, ctx.dp,
        data_axis=ctx.dp_axis, pod_axis=ctx.pod_axis,
        profiles=sparsity_profiles, topology=topo)


def make_train_step(model: Model, tcfg: TrainerConfig, param_specs,
                    param_shapes=None, sparsity_profiles=None,
                    gradsync: GradSync | None = None):
    """Returns the per-device step fn (to be wrapped in shard_map).

    ``sparsity_profiles`` (optional ``{leaf-path: SparsityProfile}``) feeds
    measured densification/skew curves into GradSync's per-tensor 'auto'
    scheme choice (otherwise the worst-case budget profile decides).
    Callers that also build the optimizer state pass the ``gradsync`` they
    got from ``make_gradsync`` so the residual shape contract is shared."""
    ctx = model.ctx
    world = _zero_world(ctx)
    zaxes = zero_axes(ctx)
    upd = UPDATES[tcfg.opt.kind]

    spec_leaves = jax.tree.leaves(
        param_specs, is_leaf=lambda x: isinstance(x, P))

    if gradsync is None:
        gradsync = make_gradsync(model, tcfg, param_specs, param_shapes,
                                 sparsity_profiles)

    def step_fn(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.train_loss, has_aux=True)(params, batch)

        # --- 2. complete model-replicated grads over the model axis --------
        if ctx.tp > 1:
            flat_g, treedef = jax.tree.flatten(grads)
            flat_g = [
                g if _has_model(s) else lax.psum(g, ctx.tp_axis)
                for g, s in zip(flat_g, spec_leaves)
            ]
            grads = jax.tree.unflatten(treedef, flat_g)

        # --- 3. data(+pod)-axis sync: bucketed, overlap-scheduled -----------
        # (with EF compression the residual memory rides in opt_state and
        # is threaded through the sync — DESIGN.md §8)
        new_residual = None
        if gradsync.has_compression:
            grads, new_residual, sync_stats = gradsync(
                grads, opt_state.get("residual", {}),
                step=opt_state["step"])
        else:
            grads, sync_stats = gradsync(grads)
        metrics = {**metrics, **sync_stats}

        # --- grad clip (global norm; sharded leaves psum over model) --------
        if tcfg.opt.grad_clip > 0:
            flat_g, _ = jax.tree.flatten(grads)
            sq = jnp.float32(0)
            for g, s in zip(flat_g, spec_leaves):
                ss = jnp.sum(g.astype(jnp.float32) ** 2)
                if ctx.tp > 1 and _has_model(s):
                    ss = lax.psum(ss, ctx.tp_axis)
                sq = sq + ss
            gn = jnp.sqrt(sq)
            scale = jnp.minimum(1.0, tcfg.opt.grad_clip / (gn + 1e-9))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
            metrics["grad_norm"] = gn

        # --- 4. parameter update --------------------------------------------
        step = opt_state["step"]
        if tcfg.zero1:
            r = lax.axis_index(zaxes) if (world > 1) else 0

            def leaf_update(p, g, st):
                c = opt_chunk_size(p.size, world)
                gf = jnp.pad(g.reshape(-1).astype(jnp.float32),
                             (0, world * c - p.size))
                pf = jnp.pad(p.reshape(-1).astype(jnp.float32),
                             (0, world * c - p.size))
                g_my = lax.dynamic_slice(gf, (r * c,), (c,))
                p_my = lax.dynamic_slice(pf, (r * c,), (c,))
                # moments arrive as this rank's [1, c] shard of [world, c]
                st_my = jax.tree.map(lambda m: m[0], st)
                p_new, st_new = upd(tcfg.opt, p_my, g_my, st_my, step)
                if world > 1:
                    p_full = lax.all_gather(p_new, zaxes, tiled=True)
                else:
                    p_full = p_new
                p_out = p_full[: p.size].reshape(p.shape).astype(p.dtype)
                st_out = jax.tree.map(lambda m: m[None], st_new)
                return p_out, st_out

            new_params, new_s = _zip_update(params, grads,
                                            opt_state["leaves"], leaf_update)
            new_state = {"leaves": new_s, "step": step + 1}
        else:
            def leaf_update_full(p, g, st):
                return upd(tcfg.opt, p, g, st, step)

            new_params, new_state_leaves = _zip_update(
                params, grads, opt_state["leaves"], leaf_update_full)
            new_state = {"leaves": new_state_leaves, "step": step + 1}

        if "residual" in opt_state:
            # EF memory: per-device state, untouched by ZeRO chunking
            new_state["residual"] = new_residual

        # report metrics averaged over data
        metrics = jax.tree.map(
            lambda m: lax.pmean(jnp.asarray(m, jnp.float32), zaxes)
            if world > 1 else jnp.asarray(m, jnp.float32), metrics)
        return new_params, new_state, metrics

    return step_fn


def _zip_update(params, grads, states, fn):
    """tree-map ``fn(p, g, st)`` where ``st`` is a sub-dict per param leaf."""
    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_s = tdef.flatten_up_to(states)
    outs = [fn(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    new_s = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    return new_p, new_s


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------

def make_prefill_step(model: Model):
    def prefill_fn(params, batch):
        logits_l, cache = model.prefill(params, batch)
        return logits_l, cache
    return prefill_fn


def make_decode_step(model: Model, window: int = 0):
    def decode_fn(params, cache, tokens):
        nxt, logit_max, cache = model.decode(params, cache, tokens,
                                             window=window)
        return nxt, logit_max, cache
    return decode_fn


# ---------------------------------------------------------------------------
# cache partition specs (mirror of Model.make_cache structure)
# ---------------------------------------------------------------------------

def cache_pspecs(model: Model) -> Any:
    cfg, ctx = model.cfg, model.ctx
    b = ctx.batch_axes
    batch = b if len(b) > 1 else b[0]

    attn = {"k": P(batch, "model", None, None),
            "v": P(batch, "model", None, None),
            "pos": P("model")}
    mla = {"c": P(batch, "model", None), "kr": P(batch, "model", None),
           "pos": P("model")}
    ssm = {"state": P(batch, "model", None, None),
           "conv": P(batch, None, "model")}

    def lift(tree, n_lead=1):
        return jax.tree.map(lambda s: P(*([None] * n_lead), *s), tree,
                            is_leaf=lambda x: isinstance(x, P))

    out: dict = {"t": P()}
    if cfg.kind == "ssm":
        out["layers"] = lift(ssm)
    elif cfg.kind == "hybrid":
        out["ssm"] = lift(ssm, 2)
        if cfg.n_layers % cfg.shared_attn_every:
            out["ssm_tail"] = lift(ssm)
        out["attn"] = lift(attn)
    elif cfg.mla_q_rank:
        out["layers"] = lift(mla)
    else:
        out["layers"] = lift(attn)
    if cfg.kind == "enc_dec":
        out["cross"] = P(None, None, batch, None, None, None)
    return out


def globalize_cache(local_tree, pspec_tree, mesh: Mesh):
    """Local-shard ShapeDtypeStructs -> global SDS given pspecs."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def leaf(sds, spec):
        shape = list(sds.shape)
        for i, ax in enumerate(spec):
            if ax is None:
                continue
            axs = ax if isinstance(ax, tuple) else (ax,)
            mult = int(np.prod([sizes[a] for a in axs]))
            shape[i] = shape[i] * mult
        return jax.ShapeDtypeStruct(tuple(shape), sds.dtype)

    return jax.tree.map(leaf, local_tree, pspec_tree)

from repro.checkpoint.io import save, restore  # noqa: F401

"""Checkpointing: pytree save/restore as flat .npz + structure manifest.

Host-gathered (fine for single-process; a multi-host deployment would write
per-process shards keyed by device — noted in DESIGN.md).  bfloat16 leaves
are stored via a uint16 view (npz has no bf16).
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

_BF16 = "__bf16__"


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path, tree) -> None:
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays = {}
    kinds = []
    for i, leaf in enumerate(leaves):
        a = np.asarray(jax.device_get(leaf))
        if a.dtype == jnp.bfloat16:
            arrays[f"leaf_{i}"] = a.view(np.uint16)
            kinds.append(_BF16)
        else:
            arrays[f"leaf_{i}"] = a
            kinds.append(str(a.dtype))
    np.savez(path / "arrays.npz", **arrays)
    (path / "manifest.json").write_text(json.dumps({
        "treedef": str(treedef), "n": len(leaves), "kinds": kinds}))
    # treedef reconstruction uses a pickle-free round trip via tree paths
    import pickle
    (path / "treedef.pkl").write_bytes(pickle.dumps(treedef))


def restore(path):
    path = Path(path)
    import pickle
    treedef = pickle.loads((path / "treedef.pkl").read_bytes())
    manifest = json.loads((path / "manifest.json").read_text())
    data = np.load(path / "arrays.npz")
    leaves = []
    for i in range(manifest["n"]):
        a = data[f"leaf_{i}"]
        if manifest["kinds"][i] == _BF16:
            a = a.view(jnp.bfloat16)
        leaves.append(jnp.asarray(a))
    return jax.tree_util.tree_unflatten(treedef, leaves)

"""HLO cost walker: trip-count-aware FLOPs / bytes / collective accounting.

``compiled.cost_analysis()`` counts each computation ONCE — a ``lax.scan``
over 62 layers contributes a single body's FLOPs, which would understate the
roofline by ~62x.  XLA does annotate loops with
``backend_config={"known_trip_count":{"n":...}}``, so this module parses the
optimized HLO text and walks the call graph, multiplying every while body
(and its collectives) by its trip count.

Heuristics (documented for §Roofline):
  * FLOPs: dots contribute 2 * |result| * contraction; elementwise ops
    contribute |result| (negligible next to dots but kept for honesty).
  * HBM bytes: 2x the result-buffer bytes of top-level (post-fusion) ops —
    every materialized buffer is written once and read ~once; fusion
    internals stay in registers/VMEM.  Operand sizes are NOT summed (a
    dynamic-slice reading 1/L of a stacked weight would otherwise charge
    the whole stack every layer).
  * Collective bytes: true per-device WIRE volumes, trip-weighted, with
    ring factors derived from the op's replica-group size g:
      all-reduce        2(g-1)/g x result      (reduce-scatter + all-gather)
      all-gather        (g-1)/g x result       (result = gathered buffer)
      reduce-scatter    (g-1)   x result       (result = scattered shard)
      all-to-all        (g-1)/g x result
      collective-permute 1      x result
  * ``exclude_bytes_re``: ops whose metadata op_name matches are excluded
    from the HBM-bytes term (used to model buffers a fused kernel keeps in
    VMEM, e.g. flash-attention score blocks); their FLOPs still count.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

SHAPE_RE = re.compile(r"([a-z]\d*[a-z]*\d*)\[([0-9,]*)\]")
COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{\s*$")
OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[a-z]\d*[a-z]*\d*"
    r"\[[0-9,]*\](?:{[^}]*})?))\s+([\w\-]+)\((.*)$")
TRIP_RE = re.compile(r'"known_trip_count":{"n":"(\d+)"}')
CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
BODY_RE = re.compile(r"body=%?([\w.\-]+)")
COND_RE = re.compile(r"condition=%?([\w.\-]+)")
BRANCHES_RE = re.compile(r"branch_computations={([^}]*)}")
TF_RE = re.compile(r"(?:true|false)_computation=%?([\w.\-]+)")
LHS_C = re.compile(r"lhs_contracting_dims={([0-9,]*)}")
OPERANDS_RE = re.compile(r"%([\w.\-]+)")
GROUPS_RE = re.compile(r"replica_groups={{([0-9,]*)}")
OPNAME_RE = re.compile(r'op_name="([^"]*)"')

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_elems_bytes(spec: str) -> tuple[int, int]:
    elems = byts = 0
    for dt, dims in SHAPE_RE.findall(spec):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * DTYPE_BYTES[dt]
    return elems, byts


@dataclasses.dataclass
class Op:
    name: str
    shape: str
    kind: str
    rest: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult


def parse_computations(hlo: str) -> Dict[str, List[Op]]:
    comps: Dict[str, List[Op]] = {}
    cur: Optional[str] = None
    entry = None
    for line in hlo.splitlines():
        m = COMP_HDR.match(line.strip()) if "{" in line else None
        if m and ("->" in line):
            cur = m.group(1)
            comps[cur] = []
            if line.strip().startswith("ENTRY"):
                entry = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        om = OP_LINE.match(line)
        if om:
            comps[cur].append(Op(om.group(1), om.group(2), om.group(3),
                                 om.group(4)))
    comps["__entry__"] = comps.get(entry, [])
    return comps


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    _, rb = shape_elems_bytes(op.shape)
    relems, _ = shape_elems_bytes(op.shape)
    # contraction size from lhs operand shape
    operands = OPERANDS_RE.findall(op.rest.split(")", 1)[0])
    contr = 1
    lm = LHS_C.search(op.rest)
    if operands and lm:
        lhs_shape = shapes.get(operands[0], "")
        m2 = SHAPE_RE.search(lhs_shape)
        if m2:
            dims = [int(d) for d in m2.group(2).split(",") if d]
            for ci in lm.group(1).split(","):
                if ci and int(ci) < len(dims):
                    contr *= dims[int(ci)]
    return 2.0 * relems * contr


def _group_size(rest: str) -> int:
    m = GROUPS_RE.search(rest)
    if not m:
        return 2
    return max(2, m.group(1).count(",") + 1)


WIRE_FACTOR = {
    "all-reduce": lambda g: 2 * (g - 1) / g,
    "all-gather": lambda g: (g - 1) / g,
    "reduce-scatter": lambda g: float(g - 1),
    "all-to-all": lambda g: (g - 1) / g,
    "collective-permute": lambda g: 1.0,
}


def walk(comps: Dict[str, List[Op]], name: str,
         memo: Dict[str, Cost], *, top: bool = True,
         exclude_bytes_re: Optional[re.Pattern] = None) -> Cost:
    if name in memo:
        return memo[name]
    memo[name] = Cost()  # cycle guard
    total = Cost()
    ops = comps.get(name, [])
    shapes = {op.name: op.shape for op in ops}
    for op in ops:
        relems, rbytes = shape_elems_bytes(op.shape)
        if exclude_bytes_re is not None:
            nm = OPNAME_RE.search(op.rest)
            if nm and exclude_bytes_re.search(nm.group(1)):
                rbytes = 0
        if op.kind == "dot":
            total.flops += _dot_flops(op, shapes)
            total.bytes += 2 * rbytes
        elif op.kind == "fusion":
            cm = CALLS_RE.search(op.rest)
            if cm:
                sub = walk(comps, cm.group(1), memo, top=False, exclude_bytes_re=exclude_bytes_re)
                total.flops += sub.flops
                for k, v in sub.coll.items():
                    total.coll[k] = total.coll.get(k, 0.0) + v
            # bytes at the fusion boundary only (result, written+read)
            total.bytes += 2 * rbytes
        elif op.kind == "while":
            bm, cm = BODY_RE.search(op.rest), COND_RE.search(op.rest)
            tm = TRIP_RE.search(op.rest)
            trip = int(tm.group(1)) if tm else 1
            if bm:
                total.add(walk(comps, bm.group(1), memo, top=False, exclude_bytes_re=exclude_bytes_re), trip)
            if cm:
                total.add(walk(comps, cm.group(1), memo, top=False, exclude_bytes_re=exclude_bytes_re), trip)
        elif op.kind == "conditional":
            branches = BRANCHES_RE.search(op.rest)
            names = ([b.strip().lstrip("%") for b in
                      branches.group(1).split(",")] if branches
                     else TF_RE.findall(op.rest))
            subs = [walk(comps, b, memo, top=False, exclude_bytes_re=exclude_bytes_re) for b in names]
            if subs:
                best = max(subs, key=lambda c: c.flops)
                total.add(best)
        elif op.kind in ("call", "async-start"):
            cm = CALLS_RE.search(op.rest) or BODY_RE.search(op.rest)
            if cm:
                total.add(walk(comps, cm.group(1), memo, top=False, exclude_bytes_re=exclude_bytes_re))
        elif op.kind.startswith(COLLECTIVES):
            kind = next(c for c in COLLECTIVES if op.kind.startswith(c))
            g = _group_size(op.rest)
            wire = WIRE_FACTOR[kind](g) * rbytes
            total.coll[kind] = total.coll.get(kind, 0.0) + wire
            total.bytes += rbytes
        elif op.kind in ("parameter", "constant", "get-tuple-element",
                         "tuple", "bitcast"):
            pass
        else:
            # elementwise / copy / reduce / gather / scatter / dynamic-slice
            total.flops += relems
            total.bytes += 2 * rbytes
    memo[name] = total
    return total


def analyze(hlo_text: str, exclude_bytes_re: str | None = None) -> dict:
    comps = parse_computations(hlo_text)
    memo: Dict[str, Cost] = {}
    pat = re.compile(exclude_bytes_re) if exclude_bytes_re else None
    c = walk(comps, "__entry__", memo, exclude_bytes_re=pat)
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collectives": {k: v for k, v in sorted(c.coll.items())},
        "collective_bytes_total": sum(c.coll.values()),
    }

"""HLO cost walker: trip-count-aware FLOPs / bytes / collective accounting.

``compiled.cost_analysis()`` counts each computation ONCE — a ``lax.scan``
over 62 layers contributes a single body's FLOPs, which would understate the
roofline by ~62x.  XLA does annotate loops with
``backend_config={"known_trip_count":{"n":...}}``, so this module walks the
parsed call graph (``repro.analysis.hlo_ir``), multiplying every while body
(and its collectives) by its trip count.

Heuristics (documented for §Roofline):
  * FLOPs: dots contribute 2 * |result| * contraction; elementwise ops
    contribute |result| (negligible next to dots but kept for honesty).
  * HBM bytes: 2x the result-buffer bytes of top-level (post-fusion) ops —
    every materialized buffer is written once and read ~once; fusion
    internals stay in registers/VMEM.  Operand sizes are NOT summed (a
    dynamic-slice reading 1/L of a stacked weight would otherwise charge
    the whole stack every layer).
  * Collective bytes: true per-device WIRE volumes, trip-weighted, with
    ring factors derived from the op's replica-group size g (see
    ``hlo_ir.WIRE_FACTOR``).  Async pairs (``all-reduce-start``/``-done``)
    count exactly once, at the start op's result half of the tuple.
  * ``exclude_bytes_re``: ops whose metadata op_name matches are excluded
    from the HBM-bytes term (used to model buffers a fused kernel keeps in
    VMEM, e.g. flash-attention score blocks); their FLOPs still count.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.analysis.hlo_ir import (  # noqa: F401  (re-exported API)
    COLLECTIVE_KINDS as COLLECTIVES,
    DTYPE_BYTES,
    SHAPE_RE,
    WIRE_FACTOR,
    HloModule,
    HloOp,
    parse_shape,
)

LHS_C = re.compile(r"lhs_contracting_dims={([0-9,]*)}")
OPERANDS_RE = re.compile(r"%([\w.\-]+)")


def shape_elems_bytes(spec: str) -> tuple[int, int]:
    leaves = parse_shape(spec)
    return (sum(lf.elems for lf in leaves),
            sum(lf.nbytes for lf in leaves))


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult


def _dot_flops(op: HloOp, shapes: Dict[str, str]) -> float:
    relems = op.result_elems
    operands = OPERANDS_RE.findall(op.rest.split(")", 1)[0])
    contr = 1
    lm = LHS_C.search(op.rest)
    if operands and lm:
        lhs = parse_shape(shapes.get(operands[0], ""))
        if lhs:
            dims = lhs[0].dims
            for ci in lm.group(1).split(","):
                if ci and int(ci) < len(dims):
                    contr *= dims[int(ci)]
    return 2.0 * relems * contr


def walk(module: HloModule, name: str, memo: Dict[str, Cost], *,
         exclude_bytes_re: Optional[re.Pattern] = None) -> Cost:
    if name in memo:
        return memo[name]
    memo[name] = Cost()  # cycle guard
    total = Cost()
    comp = module.computations.get(name)
    ops = comp.ops if comp else []
    shapes = {op.name: op.shape for op in ops}

    def sub(child: str) -> Cost:
        return walk(module, child, memo, exclude_bytes_re=exclude_bytes_re)

    for op in ops:
        rbytes = op.result_bytes
        if exclude_bytes_re is not None and op.op_name \
                and exclude_bytes_re.search(op.op_name):
            rbytes = 0
        if op.kind == "dot":
            total.flops += _dot_flops(op, shapes)
            total.bytes += 2 * rbytes
        elif op.kind == "fusion":
            for child in op.called:
                s = sub(child)
                total.flops += s.flops
                for k, v in s.coll.items():
                    total.coll[k] = total.coll.get(k, 0.0) + v
            # bytes at the fusion boundary only (result, written+read)
            total.bytes += 2 * rbytes
        elif op.kind == "while":
            trip = op.trip_count or 1
            for child in op.called:
                total.add(sub(child), trip)
        elif op.kind == "conditional":
            subs = [sub(child) for child in op.called]
            if subs:
                total.add(max(subs, key=lambda c: c.flops))
        elif op.kind in ("call", "async-start"):
            for child in op.called:
                total.add(sub(child))
        elif op.collective is not None:
            base, role = op.collective
            if role == "done":
                continue  # the -start already charged this transfer
            data = op.wire_data_bytes
            g = op.group_size or 2
            total.coll[base] = (total.coll.get(base, 0.0)
                                + WIRE_FACTOR[base](g) * data)
            total.bytes += data if rbytes else 0
        elif op.kind in ("parameter", "constant", "get-tuple-element",
                         "tuple", "bitcast", "async-done", "async-update"):
            pass
        else:
            # elementwise / copy / reduce / gather / scatter / dynamic-slice
            total.flops += op.result_elems
            total.bytes += 2 * rbytes
    memo[name] = total
    return total


def analyze(hlo_text: str, exclude_bytes_re: str | None = None) -> dict:
    module = HloModule.parse(hlo_text)
    memo: Dict[str, Cost] = {}
    pat = re.compile(exclude_bytes_re) if exclude_bytes_re else None
    c = walk(module, module.entry_name or "", memo, exclude_bytes_re=pat)
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collectives": {k: v for k, v in sorted(c.coll.items())},
        "collective_bytes_total": sum(c.coll.values()),
    }

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  This process exists only for lower()+compile()
# against the production meshes — nothing here allocates real arrays.

import argparse      # noqa: E402
import gc            # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import ALL_ARCHS, INPUT_SHAPES, get_config  # noqa: E402
from repro.core.registry import cli_scheme_choices  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.train import steps as st  # noqa: E402
from repro.train.build import (  # noqa: E402
    attach_serve, attach_train, build_program,
)
from repro.train.steps import TrainerConfig  # noqa: E402

def dryrun_combo(arch: str, shape: str, multi_pod: bool,
                 sync_scheme: str = "zen", pad_heads: bool = False,
                 fused_attn: bool = False, moe_a2a: bool = False,
                 bucket_bytes: int | None = None,
                 compress: str = "none", node_size: int = 1,
                 alpha_beta: str | None = None,
                 calib_file: str | None = None) -> dict:
    """Lower + compile one (arch, input-shape, mesh) combination.

    Returns the record for EXPERIMENTS.md §Dry-run / §Roofline.
    ``pad_heads`` / ``fused_attn`` are the §Perf optimization knobs;
    ``bucket_bytes`` compiles the bucketed overlap schedule (DESIGN.md §7)
    so its collective count/bytes land in the record; ``compress``
    compiles the EF sparsification stack (DESIGN.md §8, e.g. 'topk:0.01')
    so induced-sparsity wire volumes are measurable on the production
    mesh; ``node_size`` compiles the hierarchical two-level sync
    (DESIGN.md §10 — the data axis splits into (dp_inter, dp_intra) and
    every bucket runs its CommPlan, so per-level collective bytes land in
    the record); ``calib_file`` plans from a measured-time calibration
    table (DESIGN.md §11 — must already exist; produce it with
    ``python -m repro.core.costmodel``) so the compiled plan matches what
    a calibrated trainer would run.
    """
    from repro.core.zen import SyncConfig

    cfg = get_config(arch)
    spec = INPUT_SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod, node_size=node_size)
    t0 = time.time()
    prog = build_program(cfg, mesh, TrainerConfig(
        sync=SyncConfig(scheme=sync_scheme, bucket_bytes=bucket_bytes,
                        compress=compress, alpha_beta=alpha_beta,
                        calib_file=calib_file)),
        pad_heads=pad_heads, moe_a2a=moe_a2a)
    mode = spec["mode"]

    if mode == "train":
        attach_train(prog, spec["seq_len"], spec["global_batch"])
        for line in prog.gradsync.describe():
            print(f"  {line}", flush=True)
        ospecs_abs = st.abstract_opt_state(prog.tcfg, prog.param_shapes,
                                           prog.model.ctx, prog.param_specs,
                                           gradsync=prog.gradsync)
        args = (prog.param_shapes, ospecs_abs, prog.batch_specs["shapes"])
        step = prog.train_step
    elif mode == "prefill":
        attach_serve(prog, spec["seq_len"], spec["global_batch"], "prefill")
        args = (prog.param_shapes, prog.batch_specs["shapes"])
        step = prog.prefill_step
    else:  # decode
        attach_serve(prog, spec["seq_len"], spec["global_batch"], "decode")
        B = spec["global_batch"]
        tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        args = (prog.param_shapes, prog.cache_specs["global_shapes"], tok)
        step = prog.decode_step

    lowered = step.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # jax 0.4.x: [dict], newer: dict
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    from repro.launch import hlo_cost
    walked = hlo_cost.analyze(
        hlo, exclude_bytes_re="flash_fusable" if fused_attn else None)

    rec = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": mode,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        # trip-count-aware walker numbers (cost_analysis counts each scan
        # body once — see hlo_cost docstring); xla_* kept for reference
        "flops_per_device": float(walked["flops"]),
        "bytes_per_device": float(walked["bytes"]),
        "xla_flops_per_device": float(cost.get("flops", -1.0)),
        "xla_bytes_per_device": float(cost.get("bytes accessed", -1.0)),
        "collectives": walked["collectives"],
        "collective_bytes_total": int(walked["collective_bytes_total"]),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", -1),
            "output_bytes": getattr(mem, "output_size_in_bytes", -1),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", -1),
        },
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
        # tokens processed by one step of this program
        "tokens_per_step": spec["global_batch"] * (
            1 if mode == "decode" else spec["seq_len"]),
    }
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, choices=ALL_ARCHS + [None])
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all (arch x shape) combos")
    ap.add_argument("--sync", default="zen",
                    choices=cli_scheme_choices())
    ap.add_argument("--bucket-bytes", type=int, default=None,
                    help="fuse dense grads into buckets of at most this "
                         "many bytes and emit the double-buffered overlap "
                         "schedule (DESIGN.md §7); default: monolithic")
    ap.add_argument("--compress", default="none",
                    help="EF-sparsify dense buckets before sync "
                         "(DESIGN.md §8), e.g. 'topk:0.01', 'randk:0.05', "
                         "'threshold:1e-3', ':noef' suffix disables error "
                         "feedback; default: none")
    ap.add_argument("--node-size", type=int, default=1,
                    help="devices per node (DESIGN.md §10): compile the "
                         "hierarchical two-level sync — the data axis "
                         "splits into (dp_inter, dp_intra) and each "
                         "bucket's CommPlan aggregates intra-node before "
                         "crossing the inter-node links")
    ap.add_argument("--alpha-beta", default=None,
                    help="α-β link override for the topology cost model "
                         "('a_intra,b_intra,a_inter,b_inter' in µs, "
                         "µs/word)")
    ap.add_argument("--calib-file", default=None,
                    help="measured-time calibration table (DESIGN.md §11) "
                         "for encode-cost-aware plan choice; must exist "
                         "(write one with `python -m repro.core.costmodel"
                         " --calib-file PATH`)")
    ap.add_argument("--pad-heads", action="store_true",
                    help="§Perf: pad+shard replicated attention heads")
    ap.add_argument("--fused-attn", action="store_true",
                    help="§Perf: account flash-attention internals as fused"
                         " (VMEM-resident, validated by the Pallas kernel)")
    ap.add_argument("--moe-a2a", action="store_true",
                    help="§Perf: token-sharded MoE all-to-all dispatch")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    archs = ALL_ARCHS if args.all or not args.arch else [args.arch]
    shapes = list(INPUT_SHAPES) if args.all or not args.shape \
        else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multi_pod]

    n_ok = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
                fp = outdir / f"{tag}.json"
                if args.skip_existing and fp.exists():
                    prev = json.loads(fp.read_text())
                    if "error" not in prev:
                        continue
                try:
                    rec = dryrun_combo(arch, shape, mp, args.sync,
                                       pad_heads=args.pad_heads,
                                       fused_attn=args.fused_attn,
                                       moe_a2a=args.moe_a2a,
                                       bucket_bytes=args.bucket_bytes,
                                       compress=args.compress,
                                       node_size=args.node_size,
                                       alpha_beta=args.alpha_beta,
                                       calib_file=args.calib_file)
                    fp.write_text(json.dumps(rec, indent=1))
                    print(f"OK   {tag}: compile={rec['compile_s']}s "
                          f"flops/dev={rec['flops_per_device']:.3e} "
                          f"coll={rec['collective_bytes_total']:.3e}B",
                          flush=True)
                    n_ok += 1
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc(limit=4)
                    fp.write_text(json.dumps(
                        {"arch": arch, "shape": shape, "mesh": mp,
                         "error": f"{type(e).__name__}: {e}"}))
                    print(f"FAIL {tag}: {type(e).__name__}: {str(e)[:200]}",
                          flush=True)
                    n_fail += 1
                jax.clear_caches()
                gc.collect()
    print(f"dry-run complete: {n_ok} ok, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())

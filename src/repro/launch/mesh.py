"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (device count is locked at first use).

Hierarchical data parallelism (DESIGN.md §10): ``node_size > 1`` splits
the ``data`` mesh dimension into nested ``(dp_inter, dp_intra)`` axes —
``dp_intra`` ranks are CONSECUTIVE devices (the contiguous grouping that
maps to a physical host/node under the default device order), so
intra-node collectives stay on fast links.  The sync stack plans against
the matching ``core/topology.py`` Topology; everything else (ZeRO, batch
sharding) just sees two nested axes instead of one.
"""
from __future__ import annotations

import jax

from repro.core.topology import DP_INTER, DP_INTRA


def split_node_axes(shape, axes, node_size: int = 1):
    """Split the ``data`` dim of a (shape, axes) mesh description into
    nested ``(dp_inter, dp_intra)`` dims.  ``node_size == 1`` returns the
    description unchanged (the flat world keeps its single data axis)."""
    shape, axes = tuple(shape), tuple(axes)
    if node_size <= 1:
        return shape, axes
    if "data" not in axes:
        raise ValueError(f"node_size={node_size} needs a 'data' axis to "
                         f"split, got axes={axes}")
    i = axes.index("data")
    dp = shape[i]
    if dp % node_size != 0:
        raise ValueError(
            f"node_size={node_size} does not divide the data axis "
            f"(size {dp}); pick a divisor of {dp}")
    return (shape[:i] + (dp // node_size, node_size) + shape[i + 1:],
            axes[:i] + (DP_INTER, DP_INTRA) + axes[i + 1:])


def make_production_mesh(*, multi_pod: bool = False, node_size: int = 1):
    """v5e production mesh: 16x16 = 256 chips per pod; 2 pods = 512 chips.

    Axes: ``data`` (batch / ZeRO) x ``model`` (tensor/expert parallel),
    plus ``pod`` (data-parallel across pods) in the multi-pod mesh.
    ``node_size`` splits ``data`` into ``(dp_inter, dp_intra)``.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(*split_node_axes(shape, axes, node_size))


def make_mesh(shape, axes, node_size: int = 1):
    """Arbitrary mesh (tests / examples), e.g. ((1, 1), ('data', 'model')).

    ``node_size > 1`` splits the ``data`` dim into ``(dp_inter,
    dp_intra)`` — devices of one node are consecutive."""
    return jax.make_mesh(*split_node_axes(shape, axes, node_size))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))

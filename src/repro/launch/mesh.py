"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (device count is locked at first use).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e production mesh: 16x16 = 256 chips per pod; 2 pods = 512 chips.

    Axes: ``data`` (batch / ZeRO) x ``model`` (tensor/expert parallel),
    plus ``pod`` (data-parallel across pods) in the multi-pod mesh.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / examples), e.g. ((1, 1), ('data', 'model'))."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))

"""Training launcher.

Examples:
  # smoke-scale local run (1 device)
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \\
      --steps 50 --seq-len 128 --global-batch 4 --mesh 1x1

  # production config (real TPU pod; mesh 16x16)
  PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b \\
      --steps 1000 --seq-len 4096 --global-batch 256 --mesh 16x16 \\
      --sync zen
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.checkpoint.io import save
from repro.configs import ALL_ARCHS, get_config
from repro.core.registry import cli_scheme_choices
from repro.core.sparsify import DensityController
from repro.core.zen import SyncConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_mesh
from repro.models.common import make_ctx
from repro.optim.optimizers import OptConfig
from repro.train.build import attach_train, build_program
from repro.train.steps import TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ALL_ARCHS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--mesh", default="1x1",
                    help="DxM or PxDxM, e.g. 16x16 or 2x16x16")
    ap.add_argument("--sync", default="zen",
                    choices=cli_scheme_choices())
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--density-budget", type=float, default=0.25)
    ap.add_argument("--bucket-bytes", type=int, default=None,
                    help="bucketed overlap schedule: fuse dense grads into "
                         "buckets of at most this many bytes (DESIGN.md §7)")
    ap.add_argument("--node-size", type=int, default=1,
                    help="devices per node (DESIGN.md §10): splits the "
                         "data axis into nested (dp_inter, dp_intra) mesh "
                         "axes and plans per-bucket two-level CommPlans "
                         "(aggregate intra-node, then cross the slow "
                         "links); must divide the data-parallel degree; "
                         "1 = flat (bit-identical to the pre-topology "
                         "trainer)")
    ap.add_argument("--alpha-beta", default=None,
                    help="α-β link override for the topology cost model: "
                         "'a_intra,b_intra,a_inter,b_inter' (µs, µs per "
                         "f32 word) or 'a,b' for every level; default: "
                         "core/topology.py's ICI/DCN-class constants")
    ap.add_argument("--compress", default="none",
                    help="EF-sparsify dense gradient buckets before sync "
                         "(DESIGN.md §8): 'topk:0.01', 'randk:0.05', "
                         "'threshold:1e-3'; append ':noef' to drop the "
                         "error-feedback residual (lossy)")
    ap.add_argument("--calib-file", default=None,
                    help="measured-time cost calibration (DESIGN.md §11): "
                         "JSON table of per-stage encode/commit/dense "
                         "times; scheme='auto' then only picks zen when "
                         "the wire win survives the MEASURED encode cost. "
                         "Missing file: CostCalibrator runs once on this "
                         "machine and writes it.  Also produced by "
                         "`python -m repro.core.costmodel`")
    ap.add_argument("--no-fused-commit", action="store_true",
                    help="run zen's commit stage as the pre-fusion "
                         "dispatch chain (scatter-add -> compact -> "
                         "bitmap-encode / unpack -> decode) instead of "
                         "the fused push/pull megakernels (DESIGN.md "
                         "§14); bit-identical output, A/B-timing knob")
    ap.add_argument("--replan-every", type=int, default=0,
                    help="adaptive density control: every N steps compare "
                         "choose_scheme on the MEASURED post-compression "
                         "densities against the live plan and rebuild "
                         "(recompile) when a bucket's dense<->zen choice "
                         "flips; 0 = static plan")
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    dims = [int(x) for x in args.mesh.split("x")]
    axes = ("pod", "data", "model")[-len(dims):]
    # eager §9/§10 validation: reject a tp or node_size that does not
    # divide the config (clear error naming the config) BEFORE jax
    # allocates the mesh
    pods, dp, tp = ([1] * (3 - len(dims)) + dims)
    make_ctx(cfg, tp, dp, pods, node_size=args.node_size)
    mesh = make_mesh(tuple(dims), axes, node_size=args.node_size)
    if args.calib_file and not Path(args.calib_file).exists():
        # calibrate once on this machine, persist, then plan from it
        from repro.core.costmodel import CostCalibrator
        print(f"calibrating encode/commit times -> {args.calib_file}")
        CostCalibrator(n=max(dp, 2), iters=3).measure().save(args.calib_file)
    tcfg = TrainerConfig(
        opt=OptConfig(lr=args.lr),
        sync=SyncConfig(scheme=args.sync,
                        density_budget=args.density_budget,
                        bucket_bytes=args.bucket_bytes,
                        compress=args.compress,
                        alpha_beta=args.alpha_beta,
                        calib_file=args.calib_file,
                        fused_commit=not args.no_fused_commit),
        zero1=not args.no_zero1)
    prog = build_program(cfg, mesh, tcfg)
    attach_train(prog, args.seq_len, args.global_batch)
    params = prog.init_params(args.seed)
    opt = prog.init_opt(params)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params / 1e6:.1f}M mesh={args.mesh} "
          f"sync={args.sync} compress={args.compress} "
          f"node_size={args.node_size}")
    # the plan a run executes is printed, not inferred (DESIGN.md §10)
    for line in prog.gradsync.describe():
        print(f"  {line}")

    # adaptive density control (DESIGN.md §8): measured post-compression
    # densities feed choose_scheme; a dense<->zen flip triggers a replan.
    # Only under scheme='auto': with an explicit scheme the resolver
    # ignores recommendations, so a disagreeing controller would flag
    # drift (and recompile) every interval without ever converging.
    controller = None
    if (args.replan_every and prog.gradsync.has_compression
            and args.sync == "auto"):
        controller = DensityController(
            prog.gradsync.compressed_buckets(),
            prog.gradsync.bucket_schemes(),
            n=prog.model.ctx.dp,
            threshold=tcfg.sync.auto_threshold,
            # hier plans live in the topology's tag space; flat keeps the
            # historical int-n decision (bit-identical picks)
            topology=(None if prog.gradsync.topology.flat
                      else prog.gradsync.topology),
            # replan decisions price encode with the same measured table
            # as the live plan (no calib -> analytic, as before)
            calib=prog.gradsync.calib)

    data = iter(SyntheticLM(cfg, DataConfig(
        seq_len=args.seq_len, batch=args.global_batch, seed=args.seed)))
    t0 = time.time()
    tokens_done = 0
    for step in range(args.steps):
        b = next(data)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, m = prog.train_step(params, opt, batch)
        tokens_done += args.global_batch * args.seq_len
        if step % args.log_every == 0 or step == args.steps - 1:
            jax.block_until_ready(m["loss"])
            dt = time.time() - t0
            print(f"step {step:5d} loss={float(m['loss']):.4f} "
                  f"tok/s={tokens_done / dt:,.0f} "
                  f"sparse_words={float(m['sync/sparse_sent_words']):,.0f} "
                  f"overflow={int(float(m['sync/overflow']))}")
        if controller is not None and step % args.log_every == 0:
            controller.observe(
                {k: float(v) for k, v in m.items()
                 if k.startswith("sync/ef_density")})
        if (controller is not None and step
                and step % args.replan_every == 0):
            drift = controller.drifted()
            if drift:
                print(f"replan @ step {step}: density drift flips "
                      f"{drift} — rebuilding plan")
                attach_train(prog, args.seq_len, args.global_batch,
                             sparsity_profiles=controller.profiles())
                controller.rebase(prog.gradsync.bucket_schemes())
        if args.ckpt_dir and args.ckpt_every and \
                step and step % args.ckpt_every == 0:
            save(Path(args.ckpt_dir) / f"step_{step}",
                 {"params": params, "step": jnp.asarray(step)})
    if args.ckpt_dir:
        save(Path(args.ckpt_dir) / "final",
             {"params": params, "step": jnp.asarray(args.steps)})
    print("done")


if __name__ == "__main__":
    main()

from repro.data.pipeline import (  # noqa: F401
    DataConfig, SyntheticLM, make_batch_specs,
)

"""Synthetic data pipeline.

Token ids follow a Zipf distribution over the vocabulary — the natural-
language frequency law that *creates* the paper's C3 skew in embedding
gradients (frequent tokens → few hot rows).  Deterministic per (seed, step,
shard) so every data-parallel rank draws a disjoint, reproducible stream.

Also provides ``make_batch_specs`` — the ShapeDtypeStruct stand-ins for the
dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    batch: int              # per-host batch (local)
    zipf: float = 1.2       # token-frequency skew
    seed: int = 0


class SyntheticLM:
    """Infinite stream of {tokens, labels} (+ frames/patches stubs)."""

    def __init__(self, cfg: ArchConfig, dc: DataConfig, shard: int = 0):
        self.cfg, self.dc, self.shard = cfg, dc, shard
        v = cfg.vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        w = ranks ** (-dc.zipf)
        self._p = w / w.sum()
        self._step = 0

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        rng = np.random.default_rng(
            (self.dc.seed, self._step, self.shard))
        self._step += 1
        cfg, dc = self.cfg, self.dc
        toks = rng.choice(cfg.vocab, size=(dc.batch, dc.seq_len + 1),
                          p=self._p).astype(np.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.kind == "enc_dec":
            batch["frames"] = rng.standard_normal(
                (dc.batch, cfg.enc_len, cfg.d_model)).astype(np.float32) * 0.02
        if cfg.kind == "vlm":
            batch["patches"] = rng.standard_normal(
                (dc.batch, cfg.n_patches, cfg.d_model)).astype(np.float32) * 0.02
        return batch


def make_batch_specs(cfg: ArchConfig, seq_len: int, global_batch: int,
                     mode: str) -> dict:
    """ShapeDtypeStruct inputs for (arch, shape) — dry-run stand-ins.

    train:   tokens/labels [B, S] (+frames/patches)
    prefill: tokens [B, S] (+frames/patches)
    decode:  tokens [B, 1] — the cache is built separately.
    """
    B, S = global_batch, seq_len
    i32 = jnp.int32
    f = jnp.bfloat16
    sds = jax.ShapeDtypeStruct
    if mode == "decode":
        return {"tokens": sds((B, 1), i32)}
    batch = {"tokens": sds((B, S), i32)}
    if mode == "train":
        batch["labels"] = sds((B, S), i32)
    if cfg.kind == "enc_dec":
        batch["frames"] = sds((B, cfg.enc_len, cfg.d_model), f)
    if cfg.kind == "vlm":
        batch["patches"] = sds((B, cfg.n_patches, cfg.d_model), f)
    return batch

"""Pallas-TPU kernel: sort-free row compaction (stream compaction).

Moves each row's live (non-``EMPTY``) entries to the front, preserving their
slot order, and pads the tail with ``EMPTY``.  This is the extraction step of
Alg. 1 (lines 19-23) and the static-shape replacement for ``nonzero()`` —
previously done with a per-row ``argsort`` (O(L log L) and a ``sort`` op in
the HLO), now sort-free (see DESIGN.md §3).

Formulation: for an output column j, the value is the unique live input entry
whose prefix-count equals j.  Rather than a serial dynamic-index store loop
(L sequential RMWs — slow on TPU), the kernel reduces a [L, BLOCK_J] hit
matrix per output tile on the VPU.  That is O(L²) integer ALU work per row —
deliberately trading ops for full vectorization, which wins for the r1+r2 ~
1e3 row lengths the capacity recipe produces but grows quadratically beyond
that (the jnp path in ``hashing.row_compact`` stays O(L); prefer it if rows
get long).  Integer adds are exact, so no MXU/f32 precision concerns apply.

Layout: mem [R, L] int32; grid (R, L / BLOCK_J); each step reads a full row
and writes one BLOCK_J-wide output tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hashing import EMPTY

BLOCK_J = 128


def _kernel(mem_ref, out_ref):
    row = mem_ref[...]                                   # [1, L] int32
    valid = row != EMPTY
    inc = valid.astype(jnp.int32)
    pos = jnp.cumsum(inc, axis=1) - 1                    # prefix rank per entry
    nnz = jnp.sum(inc)
    j0 = pl.program_id(1) * BLOCK_J
    jcol = j0 + jax.lax.broadcasted_iota(jnp.int32, (1, BLOCK_J), 1)  # [1, BJ]
    # hit[i, j]: live entry i lands in output column j
    hit = valid[0, :, None] & (pos[0, :, None] == jcol[0, None, :])   # [L, BJ]
    vals = jnp.sum(jnp.where(hit, row[0, :, None], 0), axis=0)        # [BJ]
    out_ref[...] = jnp.where(jcol < nnz, vals[None, :], EMPTY)


@functools.partial(jax.jit, static_argnames=("interpret",))
def row_compact(mem: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """mem int32 [R, L] (L a BLOCK_J multiple) -> order-preserving compaction."""
    R, L = mem.shape
    assert L % BLOCK_J == 0, "pad columns to a BLOCK_J multiple (ops.row_compact_op does)"
    return pl.pallas_call(
        _kernel,
        grid=(R, L // BLOCK_J),
        in_specs=[pl.BlockSpec((1, L), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((1, BLOCK_J), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, L), jnp.int32),
        interpret=interpret,
    )(mem)

"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.hashing import EMPTY, hash_u32

BITS = 32


def hash_stage_ref(indices: jnp.ndarray, seeds: jnp.ndarray, n: int,
                   r1: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Vectorized hash stage of Alg. 1: for each index, its partition
    p = h0(idx) mod n and candidate slots q_i = h_i(idx) mod r1 for every
    second-level hash.

    indices: int32 [C] (EMPTY-padded); seeds: uint32 [k+1].
    Returns (p int32 [C], q int32 [k, C]); EMPTY rows map to (n, r1)
    out-of-range sentinels.
    """
    valid = indices != EMPTY
    p = (hash_u32(indices, seeds[0]) % jnp.uint32(n)).astype(jnp.int32)
    qs = []
    for i in range(1, seeds.shape[0]):
        q = (hash_u32(indices, seeds[i]) % jnp.uint32(r1)).astype(jnp.int32)
        qs.append(jnp.where(valid, q, r1))
    return jnp.where(valid, p, n), jnp.stack(qs)


def bitmap_pack_ref(bits: jnp.ndarray) -> jnp.ndarray:
    """int32 0/1 [W*32] -> uint32 [W] packed words (LSB-first)."""
    w = bits.reshape(-1, BITS).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(BITS, dtype=jnp.uint32)
    return jnp.sum(w * weights, axis=1, dtype=jnp.uint32)


def bitmap_unpack_ref(words: jnp.ndarray) -> jnp.ndarray:
    """uint32 [W] -> int32 0/1 [W*32]."""
    weights = jnp.uint32(1) << jnp.arange(BITS, dtype=jnp.uint32)
    bits = (words[:, None] & weights[None, :]) != 0
    return bits.reshape(-1).astype(jnp.int32)


def coo_scatter_add_ref(out_rows: int, idx: jnp.ndarray,
                        vals: jnp.ndarray) -> jnp.ndarray:
    """Server-side aggregation oracle: out[idx[i]] += vals[i]; idx EMPTY or
    >= out_rows are dropped. vals [C, d] -> out [out_rows, d]."""
    out = jnp.zeros((out_rows, vals.shape[-1]), vals.dtype)
    tgt = jnp.where((idx == EMPTY) | (idx >= out_rows), out_rows, idx)
    return out.at[tgt].add(vals, mode="drop")


# Oracle for kernels/compact.py: the jnp cumsum+scatter compaction IS the
# XLA-backend implementation, so alias it rather than duplicating the
# formulation (a copy could never catch a bug in it).
from repro.core.hashing import row_compact as row_compact_ref  # noqa: E402,F401


def zen_encode_ref(indices: jnp.ndarray, seeds, n: int, r1: int, r2: int):
    """XLA-composition oracle for the fused encode megakernel
    (kernels/zen_encode.py): hierarchical_hash(backend="xla") +
    row_compact + per-row bitmap_pack_ref.  Returns
    (pidx [n, r1+r2], occ uint32 [n, ceil((r1+r2)/32)], overflow)."""
    from repro.core.hashing import hierarchical_hash  # deferred: cycle

    part = hierarchical_hash(
        indices, n=n, r1=r1, r2=r2, k=len(seeds) - 1,
        seeds=jnp.asarray([int(s) for s in seeds], dtype=jnp.uint32),
        backend="xla")
    pidx = row_compact_ref(part.memory)
    L = r1 + r2
    W = -(-L // BITS)
    bits = jnp.pad((pidx != EMPTY).astype(jnp.int32),
                   ((0, 0), (0, W * BITS - L)))
    occ = jnp.stack([bitmap_pack_ref(b) for b in bits])
    return pidx, occ, part.overflow


def zen_commit_push_ref(lp: jnp.ndarray, vals: jnp.ndarray,
                        cap_server: int, cap_pull: int):
    """Pure-jnp oracle for the fused commit push (kernels/zen_commit.py):
    scatter-add aggregation + mask compaction + value gather + LSB-first
    bitmap pack.  lp int32 [C] (>= cap_server dropped), vals [C(, d)] ->
    (lpos [cap_pull], vals [cap_pull(, d)], bm uint32 [ceil(cap_server/32)],
    overflow)."""
    from repro.core.hashing import compact_indices  # deferred: cycle

    squeeze = vals.ndim == 1
    v2 = vals[:, None] if squeeze else vals
    buf = coo_scatter_add_ref(cap_server, lp, v2)
    mask = jnp.any(buf != 0, axis=-1)
    lpos, overflow = compact_indices(mask, cap_pull)
    safe = jnp.where(lpos == EMPTY, 0, lpos)
    out = jnp.where((lpos == EMPTY)[:, None], 0, buf[safe])
    W = -(-cap_server // BITS)
    bits = jnp.pad(mask.astype(jnp.int32), (0, W * BITS - cap_server))
    bm = bitmap_pack_ref(bits)
    return lpos, (out[:, 0] if squeeze else out), bm, overflow


def zen_commit_pull_ref(words: jnp.ndarray, cap_server: int,
                        cap_pull: int) -> jnp.ndarray:
    """Pure-jnp oracle for the fused pull decode: per-row bitmap unpack +
    compaction.  words uint32 [n, W] -> lpos int32 [n, cap_pull]."""
    from repro.core.hashing import compact_rows  # deferred: cycle

    bits = jnp.stack([bitmap_unpack_ref(w) for w in words])
    return compact_rows(bits[:, :cap_server].astype(bool), cap_pull)[0]


def row_compact_argsort_ref(mem: jnp.ndarray) -> jnp.ndarray:
    """The pre-fast-path compaction (stable per-row argsort).  EMPTY is int32
    max, so sorting moves it to the back — but it also sorts the live values
    ascending, which the order-preserving compaction deliberately does not.
    Kept as the randomized-equivalence oracle: per row, ``sort(compact(x))``
    must equal ``argsort_compact(x)``."""
    order = jnp.argsort(mem, axis=1, stable=True)
    return jnp.take_along_axis(mem, order, axis=1)

"""Pallas-TPU megakernels: the fused Zen commit path (DESIGN.md §14).

The commit-side counterpart of ``zen_encode.py``'s encode megakernel.
Two kernels cover the server work of ``schemes.zen_commit``:

* **push fuse** — server aggregation (``scatter_add.py``), non-zero
  mask + compaction (``compact_indices``) and occupancy-bitmap packing
  (``bitmap.py``) become ONE kernel: the pushed (position, value) pairs
  enter VMEM once and the wire-format pull payload (compacted server
  positions, their values, the packed server bitmap, the pull overflow
  count) leaves once.  The 3-dispatch route materializes the
  ``[cap_server, d]`` aggregation buffer to HBM between every stage;
  here it never leaves VMEM.

* **pull fuse** — the batched decode of every server's gathered bitmap
  (``bitmap_unpack`` + ``compact_rows``) becomes one kernel with grid
  ``(n,)``: one step per server row, each unpacking its words and
  compacting the set-bit positions in a single VMEM pass.  The
  permutation gather and the final full-length apply stay in XLA — their
  output is the whole gradient, too large for a VMEM-resident kernel.

Bit-exactness contract: per aggregation slot each worker contributes at
most one update (indices are unique within a worker's partition row), and
the kernel accumulates update blocks sequentially — the same per-slot add
order as XLA's flattened scatter-add.  Mask, compaction (ascending, the
``compact_indices`` order), value gather (one-hot selection, exact) and
bitmap words (LSB-first shifts — never a matmul, whose f32 accumulation
cannot represent the high bit weights) all match the XLA formulations
word for word.  The 3-deep oracle hierarchy (fused → interpret-mode
kernel → XLA composition / unfused chain) is CI-gated in
tests/test_zen_commit_fused.py.

VMEM envelope: the push kernel's selection matrices are [BLOCK_C, Csp]
and [Csp, Lp] (+value width), the pull kernel's [Wp*32, Lp] — sized by
the compact server buffer, not the gradient, so they stay in the same
~(2|I|/n)² regime as the encode megakernel.  For much larger server
buffers, tile the compaction over Csp blocks (the cumsum is associative)
before running un-interpreted on real TPUs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hashing import EMPTY

LANES = 128
BITS = 32
BLOCK_C = 256  # update rows accumulated per sequential block


def _push_kernel(lp_ref, val_ref, lpos_ref, vals_ref, occ_ref, ovf_ref, *,
                 cap_server: int, cap_pull: int):
    lp = lp_ref[...]                                      # [1, Cp] int32
    val = val_ref[...]                                    # [Cp, D]
    Cp = lp.shape[1]
    Csp = occ_ref.shape[1] * BITS                         # padded server rows
    scol = jax.lax.broadcasted_iota(jnp.int32, (1, Csp), 1)[0]  # [Csp]

    # --- server aggregation: sequential block accumulation ----------------
    # Each worker holds at most one update per slot, so accumulating the
    # update stream in blocks applies per-slot adds in stream order — the
    # same order XLA's scatter-add applies duplicate indices.  Positions
    # >= cap_server (the EMPTY sentinel and the pad) are dropped.
    buf = jnp.zeros((Csp, val.shape[1]), val.dtype)
    for c0 in range(0, Cp, BLOCK_C):
        lpb = lp[0, c0:c0 + BLOCK_C]                      # [B]
        valb = val[c0:c0 + BLOCK_C]                       # [B, D]
        hit = (lpb[:, None] == scol[None, :]) \
            & (lpb < cap_server)[:, None]                 # [B, Csp]
        buf = buf + jnp.sum(
            jnp.where(hit[:, :, None], valb[:, None, :], 0), axis=0)

    # --- mask + compaction (compact_indices formulation, ascending) -------
    mask = jnp.any(buf != 0, axis=-1)                     # [Csp]; pad rows 0
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1
    nnz = jnp.sum(mask.astype(jnp.int32))
    Lp = lpos_ref.shape[1]
    jcol = jax.lax.broadcasted_iota(jnp.int32, (Csp, Lp), 1)
    srow = jax.lax.broadcasted_iota(jnp.int32, (Csp, Lp), 0)
    hit2 = mask[:, None] & (pos[:, None] == jcol)         # [Csp, Lp]
    comp = jnp.sum(jnp.where(hit2, srow, 0), axis=0)      # [Lp]
    kept = jnp.minimum(nnz, cap_pull)
    lane_j = jax.lax.broadcasted_iota(jnp.int32, (1, Lp), 1)
    lpos_ref[...] = jnp.where(lane_j < kept, comp[None, :], EMPTY)
    # one-hot value gather: exact (each column selects at most one row)
    vals_ref[...] = jnp.sum(
        jnp.where(hit2[:, :, None], buf[:, None, :], 0), axis=0)
    ovf_ref[...] = jnp.maximum(nnz - cap_pull, 0).reshape(1, 1)

    # --- occupancy bitmap of the SERVER mask (not a prefix: pull decoders
    # re-derive positions from it) — LSB-first shift pack ------------------
    Wp = occ_ref.shape[1]
    bits = mask.astype(jnp.uint32).reshape(Wp, BITS)
    lane = jax.lax.broadcasted_iota(jnp.uint32, (Wp, BITS), 1)
    occ_ref[...] = jnp.sum(bits << lane, axis=1, dtype=jnp.uint32)[None, :]


@functools.partial(jax.jit,
                   static_argnames=("cap_server", "cap_pull", "interpret"))
def zen_commit_push_fused(lp: jnp.ndarray, vals: jnp.ndarray, *,
                          cap_server: int, cap_pull: int,
                          interpret: bool = True):
    """lp int32 [1, Cp] (Cp a BLOCK_C multiple; entries >= cap_server are
    dropped), vals [Cp, d] -> (lpos [1, Lp], vals [Lp, d], occ uint32
    [1, Wp], ovf [1, 1]) with Lp = cap_pull rounded up to LANES and
    Wp = ceil(cap_server / 32) rounded up so Wp*32 is a LANES multiple."""
    assert lp.ndim == 2 and lp.shape[0] == 1
    assert lp.shape[1] % BLOCK_C == 0 and lp.shape[1] == vals.shape[0]
    Cp = lp.shape[1]
    D = vals.shape[1]
    Lp = -(-cap_pull // LANES) * LANES
    Csp = -(-cap_server // LANES) * LANES
    Wp = Csp // BITS
    return pl.pallas_call(
        functools.partial(_push_kernel, cap_server=cap_server,
                          cap_pull=cap_pull),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1, Cp), lambda i: (0, 0)),
            pl.BlockSpec((Cp, D), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Lp), lambda i: (0, 0)),
            pl.BlockSpec((Lp, D), lambda i: (0, 0)),
            pl.BlockSpec((1, Wp), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, Lp), jnp.int32),
            jax.ShapeDtypeStruct((Lp, D), vals.dtype),
            jax.ShapeDtypeStruct((1, Wp), jnp.uint32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(lp, vals)


def _pull_kernel(words_ref, lpos_ref, *, cap_server: int, cap_pull: int):
    w = words_ref[...]                                    # [1, Wp] uint32
    Wp = w.shape[1]
    lane = jax.lax.broadcasted_iota(jnp.uint32, (Wp, BITS), 1)
    bits = ((w[0][:, None] >> lane) & jnp.uint32(1)).astype(jnp.int32)
    m = bits.reshape(Wp * BITS)                           # [Wp*32]
    col = jax.lax.broadcasted_iota(jnp.int32, (Wp * BITS, 1), 0)[:, 0]
    live = (m == 1) & (col < cap_server)                  # trim pad bits
    pos = jnp.cumsum(live.astype(jnp.int32)) - 1
    nnz = jnp.sum(live.astype(jnp.int32))
    Lp = lpos_ref.shape[1]
    jcol = jax.lax.broadcasted_iota(jnp.int32, (Wp * BITS, Lp), 1)
    hit = live[:, None] & (pos[:, None] == jcol)          # [Wp*32, Lp]
    comp = jnp.sum(jnp.where(hit, col[:, None], 0), axis=0)
    kept = jnp.minimum(nnz, cap_pull)
    lane_j = jax.lax.broadcasted_iota(jnp.int32, (1, Lp), 1)
    lpos_ref[...] = jnp.where(lane_j < kept, comp[None, :], EMPTY)


@functools.partial(jax.jit,
                   static_argnames=("cap_server", "cap_pull", "interpret"))
def zen_commit_pull_fused(words: jnp.ndarray, *, cap_server: int,
                          cap_pull: int, interpret: bool = True):
    """words uint32 [n, Wp] (per-server gathered bitmaps, Wp*32 a LANES
    multiple) -> lpos int32 [n, Lp]: each row's set-bit positions below
    ``cap_server``, compacted ascending and EMPTY-padded, first
    ``cap_pull`` kept.  Lp = cap_pull rounded up to LANES."""
    assert words.ndim == 2 and (words.shape[1] * BITS) % LANES == 0
    n, Wp = words.shape
    Lp = -(-cap_pull // LANES) * LANES
    return pl.pallas_call(
        functools.partial(_pull_kernel, cap_server=cap_server,
                          cap_pull=cap_pull),
        grid=(n,),
        in_specs=[pl.BlockSpec((1, Wp), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, Lp), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, Lp), jnp.int32)],
        interpret=interpret,
    )(words)[0]

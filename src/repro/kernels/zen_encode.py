"""Pallas-TPU megakernel: the fused Zen encode path (DESIGN.md §11).

Fuses the three encode dispatches — hash stage (``hash_stage.py``), the k
round-synchronous insertion rounds + serial memory (previously XLA
scatter_min in ``hashing.hierarchical_hash``), row extraction
(``compact.py``) and occupancy bitmap packing (``bitmap.py``) — into ONE
kernel: gradient indices enter VMEM once and the wire-format outputs
(compacted per-partition indices, packed occupancy bitmap, overflow count)
leave once.  The 3-dispatch route materializes the n x (r1+r2) index memory
to HBM twice (after hashing, after compaction); here it never leaves VMEM.

Key structural fact that makes the fusion a clean grid: every insertion
round, the serial-memory placement, the extraction and the bitmap of an
index happen entirely within its h0-partition's row.  So the grid is
``(n,)`` — one step per partition, each step fully self-contained:

  grid step i:
    in   idx   [1, Cp]   the whole EMPTY-padded index set (same block
                         every step; partitions filter by h0)
    out  pidx  [1, Lp]   partition i's compacted indices (slot order)
    out  occ   [1, Wp]   uint32 occupancy bitmap of the compacted row
    out  ovf   [1, 1]    partition i's serial-memory overflow count

Race-free by construction: the scatter_min race of the XLA path becomes a
per-slot min-reduction over an explicit [Cp, Lp] proposal matrix (the same
O(C·L) vectorized-ALU trade as ``compact.py``'s hit matrix), and the
"write-and-read" collision check becomes an exact winner test — indices are
unique (they come from ``compact_indices``), so ``idx == min(proposals)``
identifies the winner with no ties.  Bit-exactness vs both oracles
(interpret-mode 3-dispatch and XLA composition) is CI-gated
(tests/test_zen_encode_fused.py).

VMEM envelope: the proposal/hit matrices are [Cp, Lp] and [Lp, Lp] int32
per grid step.  For the capacity recipe (r1 = 2|I|/n, r2 = r1/10) that is
~(2|I|/n)² words — fine for the |I| ~ 1e4-per-bucket regime the bucketed
schedule produces; for much larger single buckets, tile the round loop
over Cp blocks (the reduction is associative) before running un-interpreted
on real TPUs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hashing import EMPTY
from repro.kernels.hash_stage import _hash_u32

LANES = 128
BITS = 32


def _kernel(idx_ref, pidx_ref, occ_ref, ovf_ref, *,
            seeds: tuple, n: int, r1: int, r2: int):
    i = pl.program_id(0)
    idx = idx_ref[...]                                    # [1, Cp] int32
    valid = idx != EMPTY
    # --- hash stage: h0 picks the partition; this step keeps only its own --
    p = (_hash_u32(idx, seeds[0]) % jnp.uint32(n)).astype(jnp.int32)
    pending = valid & (p == i)                            # [1, Cp]

    Lp = pidx_ref.shape[1]
    slot = jax.lax.broadcasted_iota(jnp.int32, (1, Lp), 1)  # [1, Lp]
    row = jnp.full((1, Lp), EMPTY, dtype=jnp.int32)

    # --- k round-synchronous insertion rounds (Alg. 1 parallel memory) -----
    # cand[c, l]: pending index c proposes slot l this round; prop masks to
    # currently-empty slots; the per-slot min over proposers IS scatter_min.
    for s in seeds[1:]:
        q = (_hash_u32(idx, s) % jnp.uint32(r1)).astype(jnp.int32)  # [1, Cp]
        cand = pending[0, :, None] & (q[0, :, None] == slot[0, None, :])
        prop = cand & (row[0, None, :] == EMPTY)          # [Cp, Lp]
        m = jnp.min(jnp.where(prop, idx[0, :, None], EMPTY), axis=0)  # [Lp]
        won = jnp.any(prop & (m[None, :] == idx[0, :, None]), axis=1)  # [Cp]
        row = jnp.minimum(row, m[None, :])
        pending = pending & ~won[None, :]

    # --- serial memory: cumsum rank ≙ the paper's atomicAdd counter --------
    surv = pending
    rank = jnp.cumsum(surv.astype(jnp.int32), axis=1) - 1  # [1, Cp]
    fits = surv & (rank < r2)
    tgt = r1 + rank
    hit = fits[0, :, None] & (tgt[0, :, None] == slot[0, None, :])
    srow = jnp.min(jnp.where(hit, idx[0, :, None], EMPTY), axis=0)
    row = jnp.minimum(row, srow[None, :])
    ovf_ref[...] = jnp.sum((surv & ~fits).astype(jnp.int32), keepdims=True)

    # --- extraction: order-preserving compaction (compact.py formulation) --
    lvalid = row != EMPTY
    pos = jnp.cumsum(lvalid.astype(jnp.int32), axis=1) - 1
    nnz = jnp.sum(lvalid.astype(jnp.int32))
    hit2 = lvalid[0, :, None] & (pos[0, :, None] == slot[0, None, :])
    comp = jnp.sum(jnp.where(hit2, row[0, :, None], 0), axis=0)       # [Lp]
    pidx_ref[...] = jnp.where(slot < nnz, comp[None, :], EMPTY)

    # --- occupancy bitmap of the COMPACTED row: a prefix of nnz ones -------
    Wp = occ_ref.shape[1]
    w_iota = jax.lax.broadcasted_iota(jnp.int32, (Wp, BITS), 0)
    lane = jax.lax.broadcasted_iota(jnp.uint32, (Wp, BITS), 1)
    bit = ((w_iota * BITS + lane.astype(jnp.int32)) < nnz).astype(jnp.uint32)
    occ_ref[...] = jnp.sum(bit << lane, axis=1, dtype=jnp.uint32)[None, :]


@functools.partial(jax.jit,
                   static_argnames=("seeds", "n", "r1", "r2", "interpret"))
def zen_encode_fused(indices: jnp.ndarray, *, seeds: tuple, n: int,
                     r1: int, r2: int, interpret: bool = True):
    """indices int32 [1, Cp] (EMPTY-padded, Cp a LANES multiple) ->
    (pidx [n, Lp], occ uint32 [n, Lp/32], ovf [n, 1]) with Lp = r1+r2
    rounded up to LANES.  ``seeds``: k+1 compile-time python ints."""
    assert indices.ndim == 2 and indices.shape[0] == 1
    assert indices.shape[1] % LANES == 0
    L = r1 + r2
    Lp = -(-L // LANES) * LANES
    Wp = Lp // BITS
    Cp = indices.shape[1]
    return pl.pallas_call(
        functools.partial(_kernel, seeds=seeds, n=n, r1=r1, r2=r2),
        grid=(n,),
        in_specs=[pl.BlockSpec((1, Cp), lambda i: (0, 0))],
        out_specs=[
            pl.BlockSpec((1, Lp), lambda i: (i, 0)),
            pl.BlockSpec((1, Wp), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, Lp), jnp.int32),
            jax.ShapeDtypeStruct((n, Wp), jnp.uint32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
        ],
        interpret=interpret,
    )(indices)

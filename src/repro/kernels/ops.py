"""Jitted public wrappers around the Pallas kernels.

These handle padding / reshaping to the kernels' tile layouts and expose the
same signatures as the pure-jnp references in ``ref.py``.  ``interpret=True``
(the default on CPU) executes the kernel bodies in Python for validation;
on TPU pass ``interpret=False``.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.hashing import EMPTY
from repro.kernels import bitmap as _bm
from repro.kernels import compact as _cp
from repro.kernels import hash_stage as _hs
from repro.kernels import scatter_add as _sa

LANES = _hs.LANES
BITS = _bm.BITS


def default_interpret() -> bool:
    """Pallas interpret mode default: real kernels on TPU, interpret (jax-op
    emulation, a correctness vehicle) everywhere else."""
    return jax.default_backend() != "tpu"


def _resolve(interpret):
    return default_interpret() if interpret is None else interpret


def hash_stage_op(indices: jnp.ndarray, seeds, n: int, r1: int,
                  *, interpret: bool | None = None):
    """indices int32 [C] -> (p [C], q [k, C]) via the Pallas kernel."""
    interpret = _resolve(interpret)
    seeds = tuple(int(s) for s in seeds)
    C = indices.shape[0]
    pad = (-C) % (LANES * _hs.BLOCK_ROWS)
    idx2 = jnp.pad(indices, (0, pad), constant_values=EMPTY)
    idx2 = idx2.reshape(-1, LANES)
    p, q = _hs.hash_stage(idx2, seeds=seeds, n=n, r1=r1, interpret=interpret)
    return p.reshape(-1)[:C], q.reshape(len(seeds) - 1, -1)[:, :C]


def bitmap_pack_op(mask: jnp.ndarray, *, interpret: bool | None = None):
    """bool/int [M] -> uint32 [ceil(M/32)] packed words."""
    interpret = _resolve(interpret)
    M = mask.shape[0]
    W = -(-M // BITS)
    padW = (-W) % _bm.BLOCK_W
    bits = jnp.pad(mask.astype(jnp.int32), (0, (W + padW) * BITS - M))
    words = _bm.bitmap_pack(bits.reshape(-1, BITS), interpret=interpret)
    return words[:W]


def bitmap_unpack_op(words: jnp.ndarray, length: int, *,
                     interpret: bool | None = None):
    """uint32 [W] -> bool [length]."""
    interpret = _resolve(interpret)
    W = words.shape[0]
    padW = (-W) % _bm.BLOCK_W
    wp = jnp.pad(words, (0, padW))
    bits = _bm.bitmap_unpack(wp, interpret=interpret)
    return bits.reshape(-1)[:length].astype(bool)


def row_compact_op(mem: jnp.ndarray, *, interpret: bool | None = None):
    """int32 [R, L] -> [R, L] live entries compacted to the front (slot order
    preserved), EMPTY-padded tail."""
    interpret = _resolve(interpret)
    R, L = mem.shape
    pad = (-L) % _cp.BLOCK_J
    memp = jnp.pad(mem, ((0, 0), (0, pad)), constant_values=EMPTY)
    return _cp.row_compact(memp, interpret=interpret)[:, :L]


def coo_scatter_add_op(out: jnp.ndarray, idx: jnp.ndarray, vals: jnp.ndarray,
                       *, interpret: bool | None = None):
    """out [M, d] += vals [C, d] at row idx [C] (EMPTY dropped)."""
    interpret = _resolve(interpret)
    C = idx.shape[0]
    pad = (-C) % _sa.BLOCK_C
    idxp = jnp.pad(idx, (0, pad), constant_values=EMPTY)
    valsp = jnp.pad(vals, ((0, pad), (0, 0)))
    return _sa.coo_scatter_add(out, idxp, valsp, interpret=interpret)

"""Jitted public wrappers around the Pallas kernels.

These handle padding / reshaping to the kernels' tile layouts and expose the
same signatures as the pure-jnp references in ``ref.py``.  ``interpret=True``
(the default on CPU) executes the kernel bodies in Python for validation;
on TPU pass ``interpret=False``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.hashing import EMPTY
from repro.kernels import bitmap as _bm
from repro.kernels import compact as _cp
from repro.kernels import hash_stage as _hs
from repro.kernels import scatter_add as _sa
from repro.kernels import zen_commit as _zc
from repro.kernels import zen_encode as _ze

LANES = _hs.LANES
BITS = _bm.BITS


def default_interpret() -> bool:
    """Pallas interpret mode default: real kernels on TPU, interpret (jax-op
    emulation, a correctness vehicle) everywhere else."""
    return jax.default_backend() != "tpu"


def _resolve(interpret):
    return default_interpret() if interpret is None else interpret


def hash_stage_op(indices: jnp.ndarray, seeds, n: int, r1: int,
                  *, interpret: bool | None = None):
    """indices int32 [C] -> (p [C], q [k, C]) via the Pallas kernel."""
    interpret = _resolve(interpret)
    seeds = tuple(int(s) for s in seeds)
    C = indices.shape[0]
    pad = (-C) % (LANES * _hs.BLOCK_ROWS)
    idx2 = jnp.pad(indices, (0, pad), constant_values=EMPTY)
    idx2 = idx2.reshape(-1, LANES)
    p, q = _hs.hash_stage(idx2, seeds=seeds, n=n, r1=r1, interpret=interpret)
    return p.reshape(-1)[:C], q.reshape(len(seeds) - 1, -1)[:, :C]


def bitmap_pack_op(mask: jnp.ndarray, *, interpret: bool | None = None):
    """bool/int [M] -> uint32 [ceil(M/32)] packed words."""
    interpret = _resolve(interpret)
    M = mask.shape[0]
    W = -(-M // BITS)
    padW = (-W) % _bm.BLOCK_W
    bits = jnp.pad(mask.astype(jnp.int32), (0, (W + padW) * BITS - M))
    words = _bm.bitmap_pack(bits.reshape(-1, BITS), interpret=interpret)
    return words[:W]


def bitmap_unpack_op(words: jnp.ndarray, length: int, *,
                     interpret: bool | None = None):
    """uint32 [W] -> bool [length]."""
    interpret = _resolve(interpret)
    W = words.shape[0]
    padW = (-W) % _bm.BLOCK_W
    wp = jnp.pad(words, (0, padW))
    bits = _bm.bitmap_unpack(wp, interpret=interpret)
    return bits.reshape(-1)[:length].astype(bool)


def row_compact_op(mem: jnp.ndarray, *, interpret: bool | None = None):
    """int32 [R, L] -> [R, L] live entries compacted to the front (slot order
    preserved), EMPTY-padded tail."""
    interpret = _resolve(interpret)
    R, L = mem.shape
    pad = (-L) % _cp.BLOCK_J
    memp = jnp.pad(mem, ((0, 0), (0, pad)), constant_values=EMPTY)
    return _cp.row_compact(memp, interpret=interpret)[:, :L]


def coo_scatter_add_op(out: jnp.ndarray, idx: jnp.ndarray, vals: jnp.ndarray,
                       *, interpret: bool | None = None):
    """out [M, d] += vals [C, d] at row idx [C] (EMPTY dropped)."""
    interpret = _resolve(interpret)
    C = idx.shape[0]
    pad = (-C) % _sa.BLOCK_C
    idxp = jnp.pad(idx, (0, pad), constant_values=EMPTY)
    valsp = jnp.pad(vals, ((0, pad), (0, 0)))
    return _sa.coo_scatter_add(out, idxp, valsp, interpret=interpret)


def batched_coo_reduce_op(out: jnp.ndarray, idx: jnp.ndarray,
                          vals: jnp.ndarray, *, backend: str = "xla",
                          interpret: bool | None = None):
    """One batched segment-reduce for every scheme's server aggregation:
    per-peer COO segments ``idx [n, C]`` / ``vals [n, C(, d)]`` (or
    already-flat) scatter-added into a dense accumulator ``out [M(, d)]``.
    EMPTY and out-of-range indices are dropped.

    This is the shared aggregation primitive of agsparse / sparse_ps /
    balanced / zen (``core/schemes.py`` routes all of them through it).
    ``backend="xla"`` is the flattened ``.at[].add`` every scheme used
    before the hoist — bit-identical updates in identical order;
    ``backend="pallas"`` routes through the sequential-grid RMW kernel
    (kernels/scatter_add.py), widening 1-D values to 2-D for it."""
    idx = idx.reshape(-1)
    vals = vals.reshape(idx.shape[0], *out.shape[1:])
    if backend != "pallas":
        tgt = jnp.where(idx == EMPTY, out.shape[0], idx)
        return out.at[tgt].add(vals, mode="drop")
    squeeze = out.ndim == 1
    out2 = out[:, None] if squeeze else out
    vals2 = vals[:, None] if squeeze else vals
    res = coo_scatter_add_op(out2, idx, vals2, interpret=interpret)
    return res[:, 0] if squeeze else res


def bitmap_pack_rows_op(mask: jnp.ndarray, *, interpret: bool | None = None):
    """bool/int [n, L] -> uint32 [n, ceil(L/32)]: per-row packed occupancy.

    Rows are padded to a word boundary before flattening, so each row's
    words are exactly the 1-D ``bitmap_pack_op`` of that row.
    """
    n, L = mask.shape
    W = -(-L // BITS)
    m = jnp.pad(mask.astype(jnp.int32), ((0, 0), (0, W * BITS - L)))
    return bitmap_pack_op(m.reshape(-1), interpret=interpret).reshape(n, W)


@functools.partial(jax.jit, static_argnames=("seeds", "n", "r1", "r2"))
def _zen_encode_fused_xla(indices: jnp.ndarray, seeds: tuple, n: int,
                          r1: int, r2: int):
    """Single-dispatch XLA composition of the fused encode — hash,
    insertion rounds, extraction, and bitmap pack in ONE executable, no
    intermediate dispatch or HBM round-trip at the jax level.

    Bit-exact re-derivation of ``hierarchical_hash`` + ``row_compact`` +
    pack, tuned for CPU/GPU XLA where scatter cost is a per-update loop:

    * nnz-adaptive lane budget: ``compact_indices`` places every live
      index before the EMPTY pad, and EMPTY candidates can never win a
      slot, take a serial rank, or overflow — so a ``lax.switch`` over
      static slice sizes {cap, cap/2, cap/4} processes only the smallest
      prefix covering the live count.  At d=0.01 with the standard 4x
      capacity margin that is 4x fewer scatter updates per round.
    * serial ranks from a transposed segmented cumsum ([n, C], contiguous
      along the scan axis) instead of ``partition_rank``'s [C, n] layout.
    * gather-only extraction: binary search over each row's validity
      cumsum replaces the compaction scatter, and the occupancy bitmap is
      a prefix-of-nnz mask packed with shifts (no scatter, no sort).
    """
    row = r1 + r2
    cap = indices.shape[0]
    sd = jnp.asarray(seeds, dtype=jnp.uint32)
    from repro.core.hashing import hash_mod

    def pipeline(idxs):
        C = idxs.shape[0]
        valid = idxs != EMPTY
        p = jnp.clip(hash_mod(idxs, sd[0], n), 0, n - 1)
        base = p * row
        mem = jnp.full((n * row,), EMPTY, dtype=jnp.int32)
        pending = valid
        for i in range(1, len(seeds)):
            slot = base + jnp.clip(hash_mod(idxs, sd[i], r1), 0, r1 - 1)
            occupied = mem[slot] != EMPTY
            propose = pending & ~occupied
            cand = jnp.where(propose, idxs, EMPTY)
            mem = mem.at[slot].min(cand, mode="drop")
            won = pending & (mem[slot] == idxs) & propose
            pending = pending & ~won
        surv = pending
        onehot = (p[None, :] == jnp.arange(n, dtype=p.dtype)[:, None]) \
            & surv[None, :]
        seg = jnp.cumsum(onehot.astype(jnp.int32), axis=1) - 1
        rank = jnp.where(surv, seg[p, jnp.arange(C)], -1)
        fits = surv & (rank < r2)
        slot = base + r1 + jnp.clip(rank, 0, r2 - 1)
        mem = mem.at[jnp.where(fits, slot, n * row)].set(
            jnp.where(fits, idxs, EMPTY), mode="drop")
        overflow = jnp.sum((surv & ~fits).astype(jnp.int32))
        mem = mem.reshape(n, row)
        v = mem != EMPTY
        cum = jnp.cumsum(v.astype(jnp.int32), axis=1)
        nnz_row = cum[:, -1:]
        q = jnp.arange(1, row + 1, dtype=jnp.int32)[None, :]
        lo = jnp.zeros((n, row), jnp.int32)
        hi = jnp.full((n, row), row - 1, jnp.int32)
        for _ in range(max(row - 1, 1).bit_length()):
            mid = (lo + hi) // 2
            go_right = jnp.take_along_axis(cum, mid, axis=1) < q
            lo = jnp.where(go_right, mid + 1, lo)
            hi = jnp.where(go_right, hi, mid)
        src = jnp.clip(lo, 0, row - 1)
        j = jnp.arange(row, dtype=jnp.int32)[None, :]
        live = j < nnz_row
        pidx = jnp.where(live, jnp.take_along_axis(mem, src, axis=1), EMPTY)
        W = -(-row // BITS)
        bit = jnp.pad(live.astype(jnp.uint32),
                      ((0, 0), (0, W * BITS - row))).reshape(n, W, BITS)
        occ = jnp.sum(bit << jnp.arange(BITS, dtype=jnp.uint32)[None, None, :],
                      axis=2, dtype=jnp.uint32)
        return pidx, occ, overflow

    sizes = sorted({cap, -(-cap // 2), -(-cap // 4)}, reverse=True)
    if len(sizes) == 1:
        return pipeline(indices)
    nnz = jnp.sum((indices != EMPTY).astype(jnp.int32))
    bidx = sum((nnz <= s).astype(jnp.int32) for s in sizes[1:])
    return jax.lax.switch(
        bidx, [lambda x, s=s: pipeline(jax.lax.slice(x, (0,), (s,)))
               for s in sizes], indices)


def zen_encode_fused_op(indices: jnp.ndarray, seeds, n: int, r1: int,
                        r2: int, *, interpret: bool | None = None,
                        force_kernel: bool = False):
    """Fused Zen encode: ONE dispatch for hash + insertion rounds +
    extraction + bitmap pack (DESIGN.md §11).

    indices int32 [C] (EMPTY-padded, from ``compact_indices``) ->
    (pidx int32 [n, r1+r2], occ uint32 [n, ceil((r1+r2)/32)], overflow
    scalar).  Bit-exact vs ``zen_encode_unfused`` (the 3-dispatch route)
    and ``ref.zen_encode_ref`` (pure-XLA composition) — the CI
    kernel-parity matrix enforces it.

    Dispatch: on TPU (interpret=False) this is the Pallas megakernel in
    ``kernels/zen_encode.py``.  Off-TPU the megakernel's interpret-mode
    emulation would execute its dense hit matrices as real XLA ops —
    O(n·C·L) work that exists only to vectorize the TPU VPU — so the
    fused op lowers to the equivalent single-dispatch XLA composition
    instead (same outputs, one executable).  ``force_kernel=True`` runs
    the interpret-mode megakernel anyway (the parity tests' middle
    oracle: fused kernel → interpret kernel → XLA composition).
    """
    interpret = _resolve(interpret)
    seeds = tuple(int(s) for s in seeds)
    if interpret and not force_kernel:
        return _zen_encode_fused_xla(indices, seeds, n, r1, r2)
    C = indices.shape[0]
    pad = (-C) % LANES
    idx2 = jnp.pad(indices, (0, pad), constant_values=EMPTY)[None, :]
    pidx, occ, ovf = _ze.zen_encode_fused(
        idx2, seeds=seeds, n=n, r1=r1, r2=r2, interpret=interpret)
    L = r1 + r2
    W = -(-L // BITS)
    # nnz per row <= L, so the dropped tail words/columns are all-zero/EMPTY
    return pidx[:, :L], occ[:, :W], jnp.sum(ovf)


@functools.partial(jax.jit, static_argnames=("cap_server", "cap_pull"))
def _zen_commit_push_fused_xla(lp: jnp.ndarray, vals: jnp.ndarray,
                               cap_server: int, cap_pull: int):
    """Single-dispatch XLA composition of the fused commit push —
    aggregation, mask/compaction, value gather and bitmap pack in ONE
    executable.  The scatter-add is the identical flattened ``.at[].add``
    the unfused route lowers (same updates, same order — bitwise equal by
    construction), compaction is ``compact_indices`` and the pack is the
    ``formats.bitmap_encode`` weight-sum, so every word matches the
    3-dispatch chain."""
    from repro.core.hashing import compact_indices

    buf = jnp.zeros((cap_server, vals.shape[-1]), vals.dtype)
    buf = buf.at[lp].add(vals, mode="drop")
    mask = jnp.any(buf != 0, axis=-1)
    lpos, overflow = compact_indices(mask, cap_pull)
    safe = jnp.where(lpos == EMPTY, 0, lpos)
    out = jnp.where((lpos == EMPTY)[:, None], 0, buf[safe])
    pad = (-cap_server) % BITS
    bits = jnp.pad(mask.astype(jnp.uint32), (0, pad)).reshape(-1, BITS)
    weights = jnp.uint32(1) << jnp.arange(BITS, dtype=jnp.uint32)
    bm = jnp.sum(bits * weights, axis=1, dtype=jnp.uint32)
    return lpos, out, bm, overflow


def zen_commit_push_fused_op(lp: jnp.ndarray, vals: jnp.ndarray, *,
                             cap_server: int, cap_pull: int,
                             interpret: bool | None = None,
                             force_kernel: bool = False):
    """Fused Zen commit push: ONE dispatch for server aggregation + mask
    compaction + value gather + bitmap pack (DESIGN.md §14).

    lp int32 [C] server-local positions (EMPTY / >= cap_server dropped),
    vals [C(, d)] pushed values -> (lpos int32 [cap_pull], vals
    [cap_pull(, d)], bm uint32 [ceil(cap_server/32)], overflow scalar).
    Bit-exact vs ``zen_commit_push_unfused`` (the 3-dispatch chain) and
    ``ref.zen_commit_push_ref`` — the CI kernel-parity matrix enforces it.

    Dispatch mirrors ``zen_encode_fused_op``: the Pallas megakernel
    (kernels/zen_commit.py) on TPU, the equivalent single-dispatch XLA
    composition off-TPU (interpret-mode hit matrices are real XLA work
    that exists only to vectorize the VPU), ``force_kernel=True`` for the
    interpret-mode megakernel (the parity tests' middle oracle)."""
    interpret = _resolve(interpret)
    squeeze = vals.ndim == 1
    vals2 = vals[:, None] if squeeze else vals
    if interpret and not force_kernel:
        lpos, v, bm, ov = _zen_commit_push_fused_xla(
            lp, vals2, cap_server, cap_pull)
    else:
        C = lp.shape[0]
        pad = (-C) % _zc.BLOCK_C
        lpp = jnp.pad(lp, (0, pad), constant_values=EMPTY)
        vp = jnp.pad(vals2, ((0, pad), (0, 0)))
        lpos, v, bm, ov = _zc.zen_commit_push_fused(
            lpp[None, :], vp, cap_server=cap_server, cap_pull=cap_pull,
            interpret=interpret)
        W = -(-cap_server // BITS)
        lpos, v = lpos[0, :cap_pull], v[:cap_pull]
        bm, ov = bm[0, :W], ov[0, 0]
    return lpos, (v[:, 0] if squeeze else v), bm, ov


def zen_commit_push_unfused(lp: jnp.ndarray, vals: jnp.ndarray, *,
                            cap_server: int, cap_pull: int,
                            interpret: bool | None = None):
    """The pre-fusion commit-push dispatch chain: scatter-add kernel + XLA
    compaction/gather + bitmap-pack kernel.  Kept as the fused
    megakernel's oracle and the benchmark baseline
    (benchmarks/micro_sync.py ``commit_fused`` series)."""
    from repro.core.hashing import compact_indices

    squeeze = vals.ndim == 1
    vals2 = vals[:, None] if squeeze else vals
    buf = coo_scatter_add_op(
        jnp.zeros((cap_server, vals2.shape[-1]), vals2.dtype), lp, vals2,
        interpret=interpret)
    mask = jnp.any(buf != 0, axis=-1)
    lpos, overflow = compact_indices(mask, cap_pull)
    safe = jnp.where(lpos == EMPTY, 0, lpos)
    out = jnp.where((lpos == EMPTY)[:, None], 0, buf[safe])
    bm = bitmap_pack_op(mask, interpret=interpret)
    return lpos, (out[:, 0] if squeeze else out), bm, overflow


@functools.partial(jax.jit, static_argnames=("cap_server", "cap_pull"))
def _zen_commit_pull_fused_xla(words: jnp.ndarray, cap_server: int,
                               cap_pull: int):
    """Single-dispatch XLA composition of the fused pull decode: batched
    bitmap unpack + row compaction (the ``bitmap_decode_batch`` +
    ``compact_rows`` formulations, in one executable)."""
    from repro.core.hashing import compact_rows

    weights = jnp.uint32(1) << jnp.arange(BITS, dtype=jnp.uint32)
    bits = (words[:, :, None] & weights[None, None, :]) != 0
    m = bits.reshape(words.shape[0], -1)[:, :cap_server]
    return compact_rows(m, cap_pull)[0]


def zen_commit_pull_fused_op(words: jnp.ndarray, cap_server: int,
                             cap_pull: int, *,
                             interpret: bool | None = None,
                             force_kernel: bool = False):
    """Fused Zen pull decode: every gathered server bitmap unpacked and
    compacted in one dispatch.  words uint32 [n, W] -> lpos int32
    [n, cap_pull] (set-bit positions below ``cap_server``, ascending,
    EMPTY-padded).  Dispatch as ``zen_commit_push_fused_op``."""
    interpret = _resolve(interpret)
    if interpret and not force_kernel:
        return _zen_commit_pull_fused_xla(words, cap_server, cap_pull)
    n, W = words.shape
    padW = (-W) % (LANES // BITS)  # pad so each row spans whole lanes
    wp = jnp.pad(words, ((0, 0), (0, padW)))
    lpos = _zc.zen_commit_pull_fused(
        wp, cap_server=cap_server, cap_pull=cap_pull, interpret=interpret)
    return lpos[:, :cap_pull]


def zen_commit_pull_unfused(words: jnp.ndarray, cap_server: int,
                            cap_pull: int, *,
                            interpret: bool | None = None):
    """The pre-fusion pull decode: bitmap-unpack kernel + XLA row
    compaction (the fused pull kernel's oracle and bench baseline)."""
    from repro.core.hashing import compact_rows

    n, W = words.shape
    bits = bitmap_unpack_op(words.reshape(-1), n * W * BITS,
                            interpret=interpret)
    m = bits.reshape(n, W * BITS)[:, :cap_server]
    return compact_rows(m, cap_pull)[0]


def zen_encode_unfused(indices: jnp.ndarray, seeds, n: int, r1: int,
                       r2: int, *, interpret: bool | None = None):
    """The pre-fusion 3-dispatch encode: hash_stage kernel + XLA conflict
    rounds + row_compact kernel + bitmap_pack kernel.  Kept as the fused
    megakernel's interpret-mode oracle and the benchmark baseline
    (benchmarks/micro_sync.py ``encode_fused`` series)."""
    from repro.core.hashing import hierarchical_hash  # deferred: cycle

    seeds = tuple(int(s) for s in seeds)
    part = hierarchical_hash(
        indices, n=n, r1=r1, r2=r2, k=len(seeds) - 1, backend="pallas",
        interpret=interpret, static_seeds=seeds)
    pidx = row_compact_op(part.memory, interpret=interpret)
    occ = bitmap_pack_rows_op(pidx != EMPTY, interpret=interpret)
    return pidx, occ, part.overflow

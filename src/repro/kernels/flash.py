"""Pallas-TPU fused flash-attention kernel (forward).

This is the §Perf optimization that removes the dominant HBM term from the
baseline roofline: the jnp-level online-softmax (layers.flash_attention)
materializes the [Bq, chunk] score/probability blocks in HBM every
(q-block x kv-chunk) step; this kernel keeps them in VMEM.

Grid: (batch*kv_head*group, q_blocks); each program owns one q block and
iterates kv blocks with `lax.fori_loop`, carrying (m, l, o) in VMEM scratch.
Block shapes are MXU-aligned ((BQ, hd) x (hd, BK) matmuls with hd, BQ, BK
multiples of 128 where possible).  Validated against ``ref.py`` /
``layers.flash_attention`` in interpret mode (CPU) — on TPU pass
``interpret=False``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bk: int, causal: bool,
            window: int, scale: float):
    """One q-block vs all kv-blocks. q [BQ, hd]; k/v [Sk, hd]; o [BQ, hd]."""
    qi = pl.program_id(1)
    BQ, hd = q_ref.shape
    Sk = k_ref.shape[0]
    q = q_ref[...].astype(jnp.float32) * scale
    pos_q = qi * BQ + jax.lax.broadcasted_iota(jnp.int32, (BQ, 1), 0)[:, 0]

    def body(ci, carry):
        m, l, o = carry
        k = pl.load(k_ref, (pl.dslice(ci * bk, bk), slice(None)))
        v = pl.load(v_ref, (pl.dslice(ci * bk, bk), slice(None)))
        s = q @ k.astype(jnp.float32).T                      # [BQ, bk] VMEM
        pos_k = ci * bk + jax.lax.broadcasted_iota(
            jnp.int32, (1, bk), 1)[0]
        valid = pos_k[None, :] < Sk
        if causal:
            valid = valid & (pos_k[None, :] <= pos_q[:, None])
        if window > 0:
            valid = valid & (pos_k[None, :] > pos_q[:, None] - window)
        s = jnp.where(valid, s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        o_new = o * corr[:, None] + p @ v.astype(jnp.float32)
        return m_new, l_new, o_new

    nk = (Sk + bk - 1) // bk
    m0 = jnp.full((BQ,), NEG, jnp.float32)
    l0 = jnp.zeros((BQ,), jnp.float32)
    o0 = jnp.zeros((BQ, hd), jnp.float32)
    m, l, o = jax.lax.fori_loop(0, nk, body, (m0, l0, o0))
    o_ref[...] = (o / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "causal", "window",
                                             "interpret"))
def flash_fwd(q, k, v, *, bq: int = 256, bk: int = 256, causal: bool = True,
              window: int = 0, interpret: bool = True):
    """q [B, Sq, H, hd]; k, v [B, Sk, KV, hd] with H % KV == 0.

    Returns [B, Sq, H, hd].  Score blocks never leave VMEM.
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    g = H // KV
    scale = 1.0 / math.sqrt(hd)
    bq = min(bq, Sq)
    assert Sq % bq == 0, (Sq, bq)
    # collapse (B, KV, g) into the grid's major axis
    qg = q.reshape(B, Sq, KV, g, hd).transpose(0, 2, 3, 1, 4) \
          .reshape(B * KV * g, Sq, hd)
    kg = jnp.broadcast_to(k.transpose(0, 2, 1, 3)[:, :, None],
                          (B, KV, g, Sk, hd)).reshape(B * KV * g, Sk, hd)
    vg = jnp.broadcast_to(v.transpose(0, 2, 1, 3)[:, :, None],
                          (B, KV, g, Sk, hd)).reshape(B * KV * g, Sk, hd)
    grid = (B * KV * g, Sq // bq)
    out = pl.pallas_call(
        functools.partial(_kernel, bk=bk, causal=causal, window=window,
                          scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, hd), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, Sk, hd), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((None, Sk, hd), lambda h, i: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, hd), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV * g, Sq, hd), q.dtype),
        interpret=interpret,
    )(qg, kg, vg)
    return out.reshape(B, KV, g, Sq, hd).transpose(0, 3, 1, 2, 4) \
              .reshape(B, Sq, H, hd)

"""Pallas-TPU kernel: the hash stage of Algorithm 1.

Computes, for a tile of gradient indices, the first-level partition
``p = h0(idx) mod n`` and all k second-level slot candidates
``q_i = h_i(idx) mod r1`` in one VMEM pass.  This is the compute hot-spot of
Zen's sparsification path (2k+2 murmur finalizer rounds per index, pure
VPU integer ALU); the conflict resolution (scatter rounds) stays in XLA where
the TPU's sequential grid makes it a memory-bound pass (DESIGN.md §3).

Layout: indices are reshaped to [R, 128] (lane-aligned); the kernel tiles
rows with BlockSpec (BR, 128).  Hash seeds are compile-time constants (they
are drawn once per training job, exactly like the paper broadcasts seeds at
startup).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hashing import EMPTY

LANES = 128
BLOCK_ROWS = 8  # (8, 128) int32 tiles — one VREG-aligned VMEM tile


def _fmix32(h):
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def _hash_u32(x, seed: int):
    s = jnp.uint32(seed)
    h = _fmix32(x.astype(jnp.uint32) ^ s)
    return _fmix32(h ^ (s * jnp.uint32(0x9E3779B9)) ^ jnp.uint32(0x5BD1E995))


def _kernel(idx_ref, p_ref, q_ref, *, seeds: tuple, n: int, r1: int):
    idx = idx_ref[...]
    valid = idx != EMPTY
    p = (_hash_u32(idx, seeds[0]) % jnp.uint32(n)).astype(jnp.int32)
    p_ref[...] = jnp.where(valid, p, n)
    for i, s in enumerate(seeds[1:]):
        q = (_hash_u32(idx, s) % jnp.uint32(r1)).astype(jnp.int32)
        q_ref[i, ...] = jnp.where(valid, q, r1)


@functools.partial(jax.jit,
                   static_argnames=("seeds", "n", "r1", "interpret"))
def hash_stage(indices: jnp.ndarray, *, seeds: tuple, n: int, r1: int,
               interpret: bool = True):
    """indices int32 [R, 128] -> (p [R, 128], q [k, R, 128]).

    ``seeds``: tuple of k+1 python ints (compile-time).
    """
    R = indices.shape[0]
    assert indices.shape[1] == LANES
    k = len(seeds) - 1
    br = min(BLOCK_ROWS, R)
    assert R % br == 0
    grid = (R // br,)
    return pl.pallas_call(
        functools.partial(_kernel, seeds=seeds, n=n, r1=r1),
        grid=grid,
        in_specs=[pl.BlockSpec((br, LANES), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
            pl.BlockSpec((k, br, LANES), lambda i: (0, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, LANES), jnp.int32),
            jax.ShapeDtypeStruct((k, R, LANES), jnp.int32),
        ],
        interpret=interpret,
    )(indices)

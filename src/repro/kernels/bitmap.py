"""Pallas-TPU kernels: hash-bitmap pack / unpack (Algorithm 2).

Pack: 32 occupancy bits -> one uint32 word via lane-shifted integer adds
(VPU; no MXU involvement — the bit weights exceed f32's exact range so a
matmul-with-weights formulation would be lossy).
Unpack: word >> lane & 1 with a broadcasted 2-D iota (TPU requires >=2D
iota).

Layout: bits [W, 32] int32 <-> words [W] uint32; W tiled by 128 rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BITS = 32
BLOCK_W = 128


def _pack_kernel(bits_ref, words_ref):
    bits = bits_ref[...].astype(jnp.uint32)          # [BW, 32]
    lane = jax.lax.broadcasted_iota(jnp.uint32, bits.shape, 1)
    words_ref[...] = jnp.sum(bits << lane, axis=1, dtype=jnp.uint32)


def _unpack_kernel(words_ref, bits_ref):
    words = words_ref[...]                           # [BW]
    lane = jax.lax.broadcasted_iota(
        jnp.uint32, (words.shape[0], BITS), 1)
    bits_ref[...] = ((words[:, None] >> lane) & jnp.uint32(1)).astype(
        jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitmap_pack(bits: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """bits int32 0/1 [W, 32] -> uint32 [W]."""
    W = bits.shape[0]
    bw = min(BLOCK_W, W)
    assert W % bw == 0 and bits.shape[1] == BITS
    return pl.pallas_call(
        _pack_kernel,
        grid=(W // bw,),
        in_specs=[pl.BlockSpec((bw, BITS), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bw,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((W,), jnp.uint32),
        interpret=interpret,
    )(bits)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitmap_unpack(words: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """uint32 [W] -> bits int32 0/1 [W, 32]."""
    W = words.shape[0]
    bw = min(BLOCK_W, W)
    assert W % bw == 0
    return pl.pallas_call(
        _unpack_kernel,
        grid=(W // bw,),
        in_specs=[pl.BlockSpec((bw,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bw, BITS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((W, BITS), jnp.int32),
        interpret=interpret,
    )(words)

"""Pallas-TPU kernel: Mamba2 SSD chunk scan (forward).

The jnp chunked SSD (`repro.models.ssm._ssd_chunked`) materializes the
[Q, Q] decay kernel and [Q, N] state updates in HBM per (chunk, head); this
kernel keeps the whole chunk-step working set in VMEM and carries the SSD
state in persistent scratch across the sequential TPU grid (the TPU grid is
ordered, so the recurrence is race-free — same property the scatter-add
kernel relies on).

Grid: (B*H, n_chunks) with chunks minor (sequential recurrence).
Per-program blocks: dA [Q], x [Q, hd], Bm/Cm [Q, N]; scratch state [hd, N].
Validated against the pure-jnp oracle in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(dA_ref, x_ref, b_ref, c_ref, y_ref, state_out_ref, state_ref):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    dA = dA_ref[...].astype(jnp.float32)          # [Q]
    x = x_ref[...].astype(jnp.float32)            # [Q, hd]
    Bm = b_ref[...].astype(jnp.float32)           # [Q, N]
    Cm = c_ref[...].astype(jnp.float32)           # [Q, N]
    Q = dA.shape[0]

    cs = jnp.cumsum(dA)                           # [Q]
    total = cs[-1]
    # intra-chunk: lower-triangular decay kernel
    li = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.where(lj <= li, jnp.exp(cs[:, None] - cs[None, :]), 0.0)
    sBC = Cm @ Bm.T                               # [Q, Q]
    y_in = (sBC * decay) @ x                      # [Q, hd]
    # inter-chunk: carried state contribution
    state = state_ref[...]
    y_st = jnp.exp(cs)[:, None] * (Cm @ state.T)  # [Q, hd]
    y_ref[...] = (y_in + y_st).astype(y_ref.dtype)
    # state update
    w = jnp.exp(total - cs)                       # [Q]
    dS = (x * w[:, None]).T @ Bm                  # [hd, N]
    state_ref[...] = state * jnp.exp(total) + dS
    state_out_ref[...] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_fwd(xh, dA, Bm, Cm, *, chunk: int = 64, interpret: bool = True):
    """Chunked SSD scan via Pallas.

    xh: [BH, S, hd] (head-major, dt pre-multiplied into xh);
    dA: [BH, S] log-decays (dt * A); Bm, Cm: [BH, S, N] (expanded per head).
    Returns (y [BH, S, hd], final state [BH, hd, N]).
    """
    BH, S, hd = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nC = S // Q
    grid = (BH, nC)
    y, state = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, Q), lambda h, c: (h, c)),
            pl.BlockSpec((None, Q, hd), lambda h, c: (h, c, 0)),
            pl.BlockSpec((None, Q, N), lambda h, c: (h, c, 0)),
            pl.BlockSpec((None, Q, N), lambda h, c: (h, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, Q, hd), lambda h, c: (h, c, 0)),
            pl.BlockSpec((None, hd, N), lambda h, c: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, hd), jnp.float32),
            jax.ShapeDtypeStruct((BH, hd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, N), jnp.float32)],
        interpret=interpret,
    )(dA, xh, Bm, Cm)
    return y, state

"""Pallas-TPU kernel: server-side COO row aggregation (scatter-add).

The Pull-side hot loop of Zen: accumulate pushed (index, row-value) pairs
into the server's compact partition buffer.  On GPU this is atomicAdd; on
TPU the *sequential* grid makes read-modify-write race-free, so the kernel
is a plain RMW loop over the tile's entries — the TPU-idiomatic equivalent
(DESIGN.md §3).

The output buffer is aliased with an input (in-place accumulation); the
value width d should be lane-aligned (multiples of 128) for real-TPU
efficiency; interpret-mode validation accepts any d.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hashing import EMPTY

BLOCK_C = 256


def _kernel(idx_ref, vals_ref, out_in_ref, out_ref, *, rows: int):
    # out_ref aliases out_in_ref (input_output_aliases) and starts with its
    # contents; all RMW goes through out_ref.
    del out_in_ref
    def body(i, _):
        ix = idx_ref[i]
        ok = (ix != EMPTY) & (ix < rows) & (ix >= 0)
        safe = jnp.where(ok, ix, 0)
        row = pl.load(out_ref, (pl.dslice(safe, 1), slice(None)))
        val = vals_ref[i, :][None, :]
        upd = row + jnp.where(ok, val, 0).astype(row.dtype)
        pl.store(out_ref, (pl.dslice(safe, 1), slice(None)), upd)
        return 0

    jax.lax.fori_loop(0, idx_ref.shape[0], body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def coo_scatter_add(out: jnp.ndarray, idx: jnp.ndarray, vals: jnp.ndarray,
                    *, interpret: bool = True) -> jnp.ndarray:
    """out [M, d] += scatter(vals [C, d] at idx [C]); returns new out.

    EMPTY / out-of-range indices are dropped.
    """
    C = idx.shape[0]
    M, d = out.shape
    bc = min(BLOCK_C, C)
    assert C % bc == 0
    return pl.pallas_call(
        functools.partial(_kernel, rows=M),
        grid=(C // bc,),
        in_specs=[
            pl.BlockSpec((bc,), lambda i: (i,)),
            pl.BlockSpec((bc, d), lambda i: (i, 0)),
            pl.BlockSpec((M, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((M, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((M, d), out.dtype),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(idx, vals, out)

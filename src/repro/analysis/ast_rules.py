"""zenlint layer 2: AST lint enforcing the scheme-registry contract.

PR 8 collapsed five hand-maintained scheme surfaces into
``core/registry.py``; these rules keep the tree collapsed:

  AST1  no raw sync collectives (``lax.psum`` / ``all_gather`` /
        ``all_to_all`` / ``ppermute`` / ...) outside ``core/schemes.py``
        and ``kernels/`` — every wire op must flow through
        ``stage_sync`` so SyncStats, the cost model, and zenlint's R2
        wire contract see it.  Collectives over *mesh-structure* axes
        (tensor parallel ``tp_axis``, ZeRO ``zaxes``, pod mean
        ``pod_axis``) are a different subsystem and exempt — matched on
        the axis argument's source text.
  AST2  no scheme-name string comparisons (``if scheme == "zen"``,
        ``scheme in ("dense", ...)``) outside the registry surfaces —
        dispatch chains must not regrow.
  AST3  no hardcoded CLI ``choices=[...]`` containing scheme names —
        derive from ``registry.cli_scheme_choices()``.

A line can waive a finding with a ``# zenlint: ignore[ASTn]`` comment —
grep-able, reviewed, never silent.
"""
from __future__ import annotations

import ast
import os
import re
from typing import List, Optional

from repro.analysis.rules import Finding

SYNC_COLLECTIVES = {
    "psum", "pmean", "pmax", "pmin", "psum_scatter", "all_gather",
    "all_gather_invariant", "all_to_all", "ppermute",
}

# files allowed to call sync collectives directly (repo-relative)
COLLECTIVE_ALLOWED = ("src/repro/core/schemes.py", "src/repro/kernels/")

# files allowed to compare scheme-name literals: the registry itself and
# the core surfaces whose *registration/bucketing* semantics are keyed by
# name (each guarded by tier-1 tests; everything else must dispatch
# through SchemeSpec)
LITERAL_ALLOWED = (
    "src/repro/core/registry.py",
    "src/repro/core/costmodel.py",
    "src/repro/core/schemes.py",
    "src/repro/core/zen.py",
    "src/repro/core/buckets.py",
)

# axis expressions naming a non-sync mesh subsystem (TP / ZeRO / pod)
_EXEMPT_AXIS = re.compile(r"tp_axis|zaxes|pod_axis")
_WAIVER = re.compile(r"#\s*zenlint:\s*ignore\[(AST\d)\]")


def _scheme_names() -> frozenset:
    from repro.core import registry  # deferred: keeps import light
    return frozenset(registry.registered_schemes())


def _call_collective(node: ast.Call) -> Optional[str]:
    """The sync-collective name a call invokes, if any."""
    f = node.func
    if isinstance(f, ast.Attribute) and f.attr in SYNC_COLLECTIVES:
        base = f.value
        if (isinstance(base, ast.Name) and base.id == "lax") or \
                (isinstance(base, ast.Attribute) and base.attr == "lax"):
            return f.attr
    if isinstance(f, ast.Name) and f.id in SYNC_COLLECTIVES:
        return f.id
    return None


def _axis_expr_src(node: ast.Call) -> str:
    """Source text of the call's axis argument (2nd positional or the
    axis/axis_name keyword) — used for the TP/ZeRO/pod exemption."""
    cand = []
    if len(node.args) > 1:
        cand.append(node.args[1])
    for kw in node.keywords:
        if kw.arg in ("axis", "axis_name"):
            cand.append(kw.value)
    return " ".join(ast.unparse(c) for c in cand)


def _waived(lines: List[str], lineno: int, rid: str) -> bool:
    line = lines[lineno - 1] if 0 < lineno <= len(lines) else ""
    m = _WAIVER.search(line)
    return bool(m and m.group(1) == rid)


def _const_scheme_strs(node: ast.AST, names: frozenset) -> List[str]:
    """Scheme-name string constants inside a literal (str or container)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value] if node.value in names else []
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        out = []
        for elt in node.elts:
            out.extend(_const_scheme_strs(elt, names))
        return out
    return []


def check_source(src: str, relpath: str) -> List[Finding]:
    """Run AST1-AST3 on one file's source; relpath decides allowlists."""
    names = _scheme_names()
    findings: List[Finding] = []
    lines = src.splitlines()
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Finding("AST1", f"unparsable: {e}", case=relpath)]
    coll_ok = relpath.startswith(COLLECTIVE_ALLOWED)
    lit_ok = relpath.startswith(LITERAL_ALLOWED)
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            cname = _call_collective(node)
            if cname and not coll_ok \
                    and not _EXEMPT_AXIS.search(_axis_expr_src(node)) \
                    and not _waived(lines, node.lineno, "AST1"):
                findings.append(Finding(
                    "AST1",
                    f"raw sync collective lax.{cname}() — route it "
                    f"through schemes.stage_sync so SyncStats and the "
                    f"wire contract (R2) see it",
                    case=f"{relpath}:{node.lineno}"))
            for kw in node.keywords:
                if kw.arg == "choices":
                    hits = _const_scheme_strs(kw.value, names)
                    if hits and not _waived(lines, node.lineno, "AST3"):
                        findings.append(Finding(
                            "AST3",
                            f"hardcoded CLI choices with scheme name(s) "
                            f"{sorted(set(hits))} — derive from "
                            f"registry.cli_scheme_choices()",
                            case=f"{relpath}:{node.lineno}"))
        elif isinstance(node, ast.Compare) and not lit_ok:
            sides = [node.left, *node.comparators]
            hits, other_src = [], []
            for s in sides:
                got = _const_scheme_strs(s, names)
                hits.extend(got)
                if not got:
                    other_src.append(ast.unparse(s))
            # "dense" doubles as an architecture kind (models/): the bare
            # word only counts when the compared expression looks
            # scheme-ish; distinctive names (zen, agsparse, ...) always do
            if set(hits) <= {"dense"} and not re.search(
                    r"scheme|sync|plan", " ".join(other_src)):
                hits = []
            if hits and not _waived(lines, node.lineno, "AST2"):
                findings.append(Finding(
                    "AST2",
                    f"scheme-name literal comparison against "
                    f"{sorted(set(hits))} — dispatch through the "
                    f"registry (SchemeSpec), not string chains",
                    case=f"{relpath}:{node.lineno}"))
    return findings


def run_tree(root: str = "src/repro") -> List[Finding]:
    """Lint every python file under ``root`` (repo-relative paths)."""
    findings: List[Finding] = []
    for dirpath, _dirs, files in os.walk(root):
        for fn in sorted(files):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            rel = path.replace(os.sep, "/")
            with open(path) as f:
                findings.extend(check_source(f.read(), rel))
    return findings

"""Parsed-module IR over optimized HLO text.

Generalizes the ad-hoc regex walker that used to live in
``launch/hlo_cost.py`` into a small reusable IR: computations, ops with
lazily-parsed attributes (shape leaves, trip counts, replica groups, called
subcomputations, collective classification), and trip-weighted folds over
the call graph.  ``launch/hlo_cost.py`` (FLOPs/bytes roofline) and
``analysis/rules`` (the zenlint R1..R5 catalog) both build on it.

Two parsing fixes over the old walker, pinned by ``tests/test_zenlint.py``:

  * tuple-shaped op results (including nested tuples, e.g. async pairs'
    ``((f32[8]), f32[8], u32[])``) are split with balanced-paren scanning
    instead of a ``\\([^)]*\\)`` regex that silently skipped them;
  * async collective pairs (``all-reduce-start``/``-done``,
    ``collective-permute-start``/``-done``) are classified by role so wire
    bytes are counted exactly once — at the ``-start`` (whose result tuple
    carries an (operands..., results...) layout; the data leaves are the
    second half once scalar context words are dropped), never at ``-done``.
"""
from __future__ import annotations

import dataclasses
import re
from functools import cached_property
from typing import Callable, Dict, Iterator, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

# per-device wire volume as a multiple of the op's data bytes, given the
# replica-group size g (ring algorithms; see DESIGN.md §13 / hlo_cost)
WIRE_FACTOR: Dict[str, Callable[[int], float]] = {
    "all-reduce": lambda g: 2 * (g - 1) / g,
    "all-gather": lambda g: (g - 1) / g,
    "reduce-scatter": lambda g: float(g - 1),
    "all-to-all": lambda g: (g - 1) / g,
    "collective-permute": lambda g: 1.0,
}

COLLECTIVE_KINDS = tuple(WIRE_FACTOR)

SHAPE_RE = re.compile(r"([a-z]\d*[a-z]*\d*)\[([0-9,]*)\]")
COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{\s*$")
_LHS_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_ARRAY_SHAPE_RE = re.compile(
    r"^[a-z]\d*[a-z]*\d*\[[0-9,]*\](?:{[^}]*})?|^token\[\]")
_KIND_RE = re.compile(r"^\s*([\w\-]+)\((.*)$")
TRIP_RE = re.compile(r'"known_trip_count":{"n":"(\d+)"}')
CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
BODY_RE = re.compile(r"body=%?([\w.\-]+)")
COND_RE = re.compile(r"condition=%?([\w.\-]+)")
BRANCHES_RE = re.compile(r"branch_computations={([^}]*)}")
TF_RE = re.compile(r"(?:true|false)_computation=%?([\w.\-]+)")
GROUPS_RE = re.compile(r"replica_groups={{([0-9,]*)}")
GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
OPNAME_RE = re.compile(r'op_name="([^"]*)"')


@dataclasses.dataclass(frozen=True)
class ShapeLeaf:
    dtype: str
    dims: Tuple[int, ...]

    @property
    def elems(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def nbytes(self) -> int:
        return self.elems * DTYPE_BYTES.get(self.dtype, 0)


def parse_shape(spec: str) -> Tuple[ShapeLeaf, ...]:
    """All array leaves of a (possibly nested tuple) shape spec, in order."""
    leaves = []
    for dt, dims in SHAPE_RE.findall(spec):
        if dt not in DTYPE_BYTES:
            continue
        leaves.append(ShapeLeaf(dt, tuple(int(d) for d in dims.split(",")
                                          if d)))
    return tuple(leaves)


def _take_shape(s: str) -> Optional[Tuple[str, str]]:
    """Split ``s`` into (leading shape spec, remainder).

    Handles array shapes (``f32[4,8]{1,0}``) and arbitrarily nested tuple
    shapes via balanced-paren scanning — the old ``\\([^)]*\\)`` regex lost
    every op whose result tuple itself contained a tuple.
    """
    if s.startswith("("):
        depth = 0
        for i, ch in enumerate(s):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return s[:i + 1], s[i + 1:]
        return None
    m = _ARRAY_SHAPE_RE.match(s)
    if m:
        return m.group(0), s[m.end():]
    return None


def tuple_elements(spec: str) -> List[str]:
    """Top-level elements of a tuple shape spec (or [spec] for arrays)."""
    spec = spec.strip()
    if not spec.startswith("("):
        return [spec]
    inner, depth, start, out = spec[1:-1], 0, 0, []
    for i, ch in enumerate(inner):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        elif ch == "," and depth == 0:
            out.append(inner[start:i].strip())
            start = i + 1
    tail = inner[start:].strip()
    if tail:
        out.append(tail)
    return out


def split_op_line(line: str) -> Optional[Tuple[str, str, str, str]]:
    """Parse an HLO op line into (name, shape_spec, kind, rest)."""
    m = _LHS_RE.match(line)
    if not m:
        return None
    taken = _take_shape(line[m.end():])
    if not taken:
        return None
    shape, tail = taken
    km = _KIND_RE.match(tail)
    if not km:
        return None
    return m.group(1), shape, km.group(1), km.group(2)


@dataclasses.dataclass
class HloOp:
    name: str
    shape: str     # result shape spec, verbatim
    kind: str      # opcode, e.g. "all-reduce-start", "fusion", "while"
    rest: str      # operands + attributes, verbatim

    @cached_property
    def leaves(self) -> Tuple[ShapeLeaf, ...]:
        return parse_shape(self.shape)

    @property
    def result_elems(self) -> int:
        return sum(lf.elems for lf in self.leaves)

    @property
    def result_bytes(self) -> int:
        return sum(lf.nbytes for lf in self.leaves)

    @cached_property
    def trip_count(self) -> Optional[int]:
        m = TRIP_RE.search(self.rest)
        return int(m.group(1)) if m else None

    @cached_property
    def op_name(self) -> str:
        m = OPNAME_RE.search(self.rest)
        return m.group(1) if m else ""

    @cached_property
    def group_size(self) -> Optional[int]:
        """Replica-group size, or None when the op carries no groups attr."""
        m = GROUPS_RE.search(self.rest)
        if m:
            return max(1, m.group(1).count(",") + 1)
        m = GROUPS_IOTA_RE.search(self.rest)
        if m:  # iota form [G,S]<=[N]: G groups of S participants
            return int(m.group(2))
        return None

    @cached_property
    def collective(self) -> Optional[Tuple[str, str]]:
        """(base kind, role) for collective ops; role in {sync,start,done}."""
        for base in COLLECTIVE_KINDS:
            if self.kind == base:
                return base, "sync"
            if self.kind == base + "-start":
                return base, "start"
            if self.kind == base + "-done":
                return base, "done"
        return None

    @cached_property
    def wire_data_bytes(self) -> int:
        """Bytes of collective payload, counted once per start/done pair.

        ``-done`` contributes 0.  A ``-start`` result tuple is laid out as
        (operands..., results...[, context scalars]); after dropping scalar
        integer context words, the data leaves are the second half (the
        results) — taking all of them would double-charge the transfer.
        """
        if self.collective is None:
            return 0
        role = self.collective[1]
        if role == "done":
            return 0
        if role == "sync":
            return self.result_bytes
        data = [lf for lf in self.leaves
                if not (lf.dims == () and lf.dtype in ("u32", "s32", "u64",
                                                       "s64", "pred"))]
        if len(data) % 2 == 0 and data:
            data = data[len(data) // 2:]
        return sum(lf.nbytes for lf in data)

    @cached_property
    def called(self) -> Tuple[str, ...]:
        """Subcomputations this op invokes (excluding reducer to_apply)."""
        if self.kind == "while":
            names = [m.group(1) for m in (BODY_RE.search(self.rest),
                                          COND_RE.search(self.rest)) if m]
            return tuple(names)
        if self.kind == "conditional":
            b = BRANCHES_RE.search(self.rest)
            if b:
                return tuple(x.strip().lstrip("%")
                             for x in b.group(1).split(",") if x.strip())
            return tuple(TF_RE.findall(self.rest))
        m = CALLS_RE.search(self.rest) or BODY_RE.search(self.rest)
        return (m.group(1),) if m else ()


@dataclasses.dataclass
class HloComputation:
    name: str
    ops: List[HloOp] = dataclasses.field(default_factory=list)
    is_entry: bool = False


@dataclasses.dataclass
class HloModule:
    computations: Dict[str, HloComputation]
    entry_name: Optional[str]

    @classmethod
    def parse(cls, hlo_text: str) -> "HloModule":
        comps: Dict[str, HloComputation] = {}
        cur: Optional[HloComputation] = None
        entry = None
        for line in hlo_text.splitlines():
            stripped = line.strip()
            m = COMP_HDR.match(stripped) if "{" in line else None
            if m and "->" in line:
                cur = HloComputation(m.group(1),
                                     is_entry=stripped.startswith("ENTRY"))
                comps[cur.name] = cur
                if cur.is_entry:
                    entry = cur.name
                continue
            if cur is None:
                continue
            if stripped == "}":
                cur = None
                continue
            parsed = split_op_line(line)
            if parsed:
                cur.ops.append(HloOp(*parsed))
        return cls(computations=comps, entry_name=entry)

    @property
    def entry(self) -> HloComputation:
        return self.computations.get(self.entry_name or "",
                                     HloComputation("__missing__"))

    def all_ops(self) -> Iterator[Tuple[str, HloOp]]:
        """Every op in every computation (reachable or not) — for rules
        that must hold module-wide (sorts, f64, unannotated whiles)."""
        for comp in self.computations.values():
            for op in comp.ops:
                yield comp.name, op

    def fold_entry(self, op_fn, *, all_branches: bool = False,
                   merge=None) -> dict:
        """Trip-weighted fold over the call graph from the entry.

        ``op_fn(op, acc)`` mutates a per-computation dict accumulator;
        while bodies/conds multiply by ``known_trip_count`` (1 when
        absent), conditionals take the max-valued branch unless
        ``all_branches``.  ``merge(dst, src, mult)`` folds a
        subcomputation's dict into the parent's (default: numeric adds).
        """
        if merge is None:
            def merge(dst, src, mult):
                for k, v in src.items():
                    dst[k] = dst.get(k, 0.0) + v * mult
        memo: Dict[str, dict] = {}

        def walk(name: str) -> dict:
            if name in memo:
                return memo[name]
            memo[name] = {}  # cycle guard
            acc: dict = {}
            comp = self.computations.get(name)
            for op in (comp.ops if comp else []):
                op_fn(op, acc)
                if not op.called:
                    continue
                if op.kind == "while":
                    trip = op.trip_count or 1
                    for sub in op.called:
                        merge(acc, walk(sub), trip)
                elif op.kind == "conditional" and not all_branches:
                    subs = [walk(sub) for sub in op.called]
                    if subs:
                        best = max(subs,
                                   key=lambda d: sum(v for v in d.values()
                                                     if isinstance(v, (int,
                                                                       float))))
                        merge(acc, best, 1.0)
                else:
                    for sub in op.called:
                        merge(acc, walk(sub), 1.0)
            memo[name] = acc
            return acc

        return walk(self.entry_name or "")


def collective_wire(module: HloModule) -> Dict[Tuple[str, int], float]:
    """Trip-weighted per-device wire bytes keyed by (base kind, group size).

    Start/done pairs count once; conditionals contribute all branches
    (conservative for a verifier — a collective on any path is on the wire).
    """
    def op_fn(op: HloOp, acc: dict):
        if op.collective is None:
            return
        base, _role = op.collective
        b = op.wire_data_bytes
        if not b:
            return
        g = op.group_size or 2
        key = (base, g)
        acc[key] = acc.get(key, 0.0) + WIRE_FACTOR[base](g) * b

    return module.fold_entry(op_fn, all_branches=True)


def count_collectives(module: HloModule, base: Optional[str] = None) -> int:
    """Unweighted count of collective ops module-wide (pairs count once)."""
    n = 0
    for _comp, op in module.all_ops():
        if op.collective is None or op.collective[1] == "done":
            continue
        if base is None or op.collective[0] == base:
            n += 1
    return n


def find_sort_ops(text: str) -> List[str]:
    """Sort ops in either StableHLO or optimized-HLO text.

    One source of truth for the sort-free-encode claim (R1): callers hand
    in whatever ``lower().as_text()`` or ``compile().as_text()`` produced.
    """
    hits = []
    for i, line in enumerate(text.splitlines(), 1):
        if re.search(r"\bstablehlo\.sort\b|\bmhlo\.sort\b", line):
            hits.append(f"line {i}: {line.strip()[:100]}")
    module = HloModule.parse(text)
    for comp, op in module.all_ops():
        if op.kind == "sort":
            hits.append(f"{comp}: %{op.name} = sort(...)")
    return hits

"""zenlint: static analysis over lowered sync programs (DESIGN.md §13).

Two layers:

  * ``hlo_ir`` + ``rules`` — a parsed-module IR over optimized HLO text and
    a rule catalog (R1..R5) certifying the paper's claims as properties of
    the *lowered* program: sort-free encode, wire-exact collective bytes,
    no silent promotion, overlap fences intact, no dynamic fallbacks.
  * ``ast_rules`` — source-tree lint enforcing the scheme-registry contract
    (no raw sync collectives, no scheme-name literals, no dispatch chains
    outside the registry surfaces).

Driver: ``python -m repro.analysis.lint`` sweeps every registered scheme x
{flat, hier} x {n=2, 8} on the host-platform mesh.
"""

"""zenlint rule catalog: paper invariants over lowered sync programs.

Each rule takes a :class:`Subject` — one lowered program plus its
expectations — and returns :class:`Finding`s.  The catalog (DESIGN.md §13):

  R1  sort-free encode: no ``sort`` op (HLO) / ``stablehlo.sort`` reachable
      from a sync program.  PR 1's segmented-cumsum claim, machine-checked.
  R2  wire-exact: trip-weighted collective bytes per replica-group size
      equal the registry's capacity-shaped expectation exactly, and the
      program's own SyncStats claim matches (== for saturable schemes,
      <= for over-provisioned ones like zen).
  R3  no silent promotion: no f64 anywhere (no f32->f64 converts), and
      reduction accumulators never narrower than their inputs.
  R4  overlap fences present: the run_schedule pipeline keeps its
      ``optimization_barrier``s in the lowering, and no fence input
      depends on a collective (flat pipelines — encode(i+1) independent
      of commit(i), the double-buffering contract).
  R5  no dynamic fallbacks: no host callbacks / infeed / send-recv, and
      every ``while`` carries ``known_trip_count``.

Rules are registered with the :func:`rule` decorator; a scheme can waive a
rule via ``SchemeSpec.lint_exempt`` (surfaced as ``Subject.exempt``), which
the driver prints as an explicit waiver rather than silently skipping.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.analysis import hlo_ir
from repro.analysis.hlo_ir import DTYPE_BYTES, HloModule

REL_TOL = 1e-6

# jaxpr primitives that hit the wire (sync collectives under shard_map/vmap)
COLLECTIVE_PRIMS = {
    "psum", "pmax", "pmin", "pmean", "all_gather", "all_to_all", "ppermute",
    "psum_scatter", "reduce_scatter", "all_gather_invariant",
}

_HOSTISH_KINDS = ("infeed", "outfeed", "send", "recv", "send-done",
                  "recv-done")
_HOSTISH_TARGET = re.compile(r"callback|host", re.IGNORECASE)


@dataclasses.dataclass
class Finding:
    rule: str
    message: str
    case: str = ""
    computation: str = ""
    op: str = ""

    def __str__(self) -> str:
        where = "/".join(x for x in (self.computation, self.op) if x)
        loc = f" [{where}]" if where else ""
        case = f" ({self.case})" if self.case else ""
        return f"{self.rule}{case}: {self.message}{loc}"


@dataclasses.dataclass
class WireExpectation:
    """R2 expectation for one replica-group size (== one topology level)."""
    expected_bytes: float            # registry wire_words_fn x dtype bytes
    claimed_bytes: float             # SyncStats.sent_words x dtype bytes
    kinds: Tuple[str, ...]           # allowed base collective kinds
    claim_exact: bool = True         # saturable: claim == wire, else <=


@dataclasses.dataclass
class Subject:
    """One lowered program under verification."""
    label: str
    module: Optional[HloModule] = None     # optimized HLO, parsed
    stablehlo_text: str = ""               # pre-optimization lowering
    jaxpr: Any = None                      # ClosedJaxpr (R4 dependence)
    expected_fences: int = 0               # run_schedule barriers expected
    fences_collective_free: bool = False   # flat pipeline: see R4
    wire: Optional[Dict[int, WireExpectation]] = None
    exempt: Tuple[str, ...] = ()


RuleFn = Callable[[Subject], List[Finding]]
RULES: Dict[str, Tuple[str, RuleFn]] = {}


def rule(rid: str, title: str):
    def deco(fn: RuleFn) -> RuleFn:
        RULES[rid] = (title, fn)
        return fn
    return deco


def run_rules(subject: Subject) -> List[Finding]:
    findings: List[Finding] = []
    for rid in sorted(RULES):
        if rid in subject.exempt:
            continue
        _title, fn = RULES[rid]
        for f in fn(subject):
            f.case = f.case or subject.label
            findings.append(f)
    return findings


# ---------------------------------------------------------------- R1

@rule("R1", "sort-free encode")
def _r1_no_sorts(s: Subject) -> List[Finding]:
    out = []
    if s.stablehlo_text:
        for hit in hlo_ir.find_sort_ops(s.stablehlo_text):
            out.append(Finding("R1", f"sort in lowering: {hit}"))
    if s.module is not None:
        for comp, op in s.module.all_ops():
            if op.kind == "sort":
                out.append(Finding("R1", "sort op in optimized HLO",
                                   computation=comp, op=op.name))
    return out


def find_sorts(text: str) -> List[str]:
    """Shared sort check for tests: StableHLO or optimized HLO text in,
    human-readable hit descriptions out (empty == sort-free)."""
    return hlo_ir.find_sort_ops(text)


# ---------------------------------------------------------------- R2

# collective-permute carries source_target_pairs, not replica_groups — a
# single permute op's pair structure cannot recover the communicator size
# (a shift-by-4 stage on an 8-ring looks like four disjoint 2-cycles).
# Levels whose expected kinds are permute-only are therefore verified as
# a pooled byte total across all such levels instead of per group size;
# the per-level SyncStats claim is still held to the registry formula.
POOLED_KINDS = frozenset({"collective-permute"})


def _claim_findings(exp: WireExpectation, got: float, where: str
                    ) -> List[Finding]:
    if exp.claim_exact:
        if abs(exp.claimed_bytes - got) > REL_TOL * max(1.0, got):
            return [Finding(
                "R2", f"{where}: SyncStats claim {exp.claimed_bytes:.0f} B "
                      f"!= wire {got:.0f} B (scheme is marked saturable)")]
    elif exp.claimed_bytes > got * (1 + REL_TOL) + REL_TOL:
        return [Finding(
            "R2", f"{where}: SyncStats claim {exp.claimed_bytes:.0f} B "
                  f"exceeds wire {got:.0f} B")]
    return []


@rule("R2", "wire-exact collective bytes")
def _r2_wire_exact(s: Subject) -> List[Finding]:
    if s.module is None or s.wire is None:
        return []
    out = []
    pooled = {g: e for g, e in s.wire.items()
              if e.kinds and set(e.kinds) <= POOLED_KINDS}
    grouped = {g: e for g, e in s.wire.items() if g not in pooled}
    measured = hlo_ir.collective_wire(s.module)
    by_group: Dict[int, float] = {}
    pooled_got = 0.0
    for (base, g), b in measured.items():
        if base in POOLED_KINDS and pooled:
            pooled_got += b
            continue
        by_group[g] = by_group.get(g, 0.0) + b
        exp = grouped.get(g)
        if exp is None:
            out.append(Finding(
                "R2", f"collective {base} at unexpected group size {g} "
                      f"({b:.0f} wire bytes; levels expect "
                      f"{sorted(s.wire)})"))
        elif base not in exp.kinds:
            out.append(Finding(
                "R2", f"unexpected collective kind {base} at group size "
                      f"{g} (registry expects {exp.kinds})"))
    for g, exp in sorted(grouped.items()):
        got = by_group.get(g, 0.0)
        if abs(got - exp.expected_bytes) > REL_TOL * max(
                1.0, exp.expected_bytes):
            out.append(Finding(
                "R2", f"group size {g}: measured wire {got:.0f} B != "
                      f"expected {exp.expected_bytes:.0f} B"))
            continue
        out.extend(_claim_findings(exp, got, f"group size {g}"))
    if pooled:
        want = sum(e.expected_bytes for e in pooled.values())
        if abs(pooled_got - want) > REL_TOL * max(1.0, want):
            out.append(Finding(
                "R2", f"pooled collective-permute wire {pooled_got:.0f} B "
                      f"!= expected {want:.0f} B (levels {sorted(pooled)})"))
        for g, exp in sorted(pooled.items()):
            out.extend(_claim_findings(exp, exp.expected_bytes,
                                       f"group size {g} (pooled)"))
    return out


# ---------------------------------------------------------------- R3

def _operand_dtypes(op: hlo_ir.HloOp) -> List[str]:
    return [dt for dt, _dims in hlo_ir.SHAPE_RE.findall(op.rest)
            if dt in DTYPE_BYTES]


@rule("R3", "no silent promotion")
def _r3_no_promotion(s: Subject) -> List[Finding]:
    if s.module is None:
        return []
    out = []
    for comp, op in s.module.all_ops():
        if any(lf.dtype == "f64" for lf in op.leaves):
            what = ("f32->f64 convert" if op.kind == "convert"
                    else f"f64 result on {op.kind}")
            out.append(Finding("R3", f"double precision leak: {what}",
                               computation=comp, op=op.name))
        elif op.kind in ("reduce", "reduce-window"):
            ins = _operand_dtypes(op)
            res = op.leaves[0].dtype if op.leaves else None
            if ins and res and DTYPE_BYTES[res] < DTYPE_BYTES[ins[0]]:
                out.append(Finding(
                    "R3", f"reduction accumulator {res} narrower than "
                          f"input {ins[0]}", computation=comp, op=op.name))
    return out


# ---------------------------------------------------------------- R4

def _sub_jaxprs(eqn) -> List[Any]:
    subs = []
    for v in eqn.params.values():
        vals = v if isinstance(v, (list, tuple)) else (v,)
        for x in vals:
            if hasattr(x, "eqns"):
                subs.append(x)
            elif hasattr(x, "jaxpr") and hasattr(x.jaxpr, "eqns"):
                subs.append(x.jaxpr)
    return subs


def _contains_collective(jaxpr) -> bool:
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in COLLECTIVE_PRIMS:
            return True
        if any(_contains_collective(sub) for sub in _sub_jaxprs(eqn)):
            return True
    return False


def _walk_jaxprs(jaxpr):
    yield jaxpr
    for eqn in jaxpr.eqns:
        for sub in _sub_jaxprs(eqn):
            yield from _walk_jaxprs(sub)


def fence_dependence_findings(closed_jaxpr, case: str = "") -> List[Finding]:
    """Flag optimization_barrier inputs that depend on a collective.

    In the flat run_schedule pipeline every fence carries encode outputs
    only; a fence input tainted by a collective means encode(i+1) has a
    data dependence on commit(i) — the double-buffering overlap is dead.
    (Hierarchical pipelines fence the intra-stage result by design; this
    check is only run on flat subjects.)
    """
    out = []
    root = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    for j in _walk_jaxprs(root):
        tainted: set = set()
        for eqn in j.eqns:
            is_coll = (eqn.primitive.name in COLLECTIVE_PRIMS
                       or any(_contains_collective(sub)
                              for sub in _sub_jaxprs(eqn)))
            in_tainted = [v for v in eqn.invars
                          if type(v).__name__ != "Literal" and v in tainted]
            if eqn.primitive.name == "optimization_barrier" and in_tainted:
                out.append(Finding(
                    "R4", "optimization_barrier input depends on a "
                          "collective — encode(i+1) is not independent "
                          "of commit(i)", case=case,
                    op=eqn.primitive.name))
            if is_coll or in_tainted:
                tainted.update(eqn.outvars)
    return out


@rule("R4", "overlap fences present")
def _r4_fences(s: Subject) -> List[Finding]:
    out = []
    if s.expected_fences > 0:
        # the barrier survives into StableHLO; XLA's scheduler consumes it
        # during compilation, so presence is checked pre-optimization.
        got = len(re.findall(r"optimization_barrier", s.stablehlo_text))
        if got < s.expected_fences:
            out.append(Finding(
                "R4", f"expected >= {s.expected_fences} optimization_"
                      f"barriers in the lowering, found {got} — the "
                      f"run_schedule fences were dropped"))
    if s.fences_collective_free and s.jaxpr is not None:
        out.extend(fence_dependence_findings(s.jaxpr, case=s.label))
    return out


# ---------------------------------------------------------------- R5

@rule("R5", "no dynamic fallbacks")
def _r5_static(s: Subject) -> List[Finding]:
    if s.module is None:
        return []
    out = []
    for comp, op in s.module.all_ops():
        if op.kind == "while" and op.trip_count is None:
            out.append(Finding(
                "R5", "while without known_trip_count (dynamic loop in a "
                      "sync program)", computation=comp, op=op.name))
        elif op.kind in _HOSTISH_KINDS:
            out.append(Finding("R5", f"host-transfer op {op.kind}",
                               computation=comp, op=op.name))
        elif op.kind == "custom-call":
            m = re.search(r'custom_call_target="([^"]*)"', op.rest)
            if m and _HOSTISH_TARGET.search(m.group(1)):
                out.append(Finding(
                    "R5", f"host callback custom-call {m.group(1)!r}",
                    computation=comp, op=op.name))
    return out

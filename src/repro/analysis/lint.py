"""zenlint driver: certify every registered scheme's lowered program.

``python -m repro.analysis.lint`` runs three layers and exits nonzero on
any finding:

  * AST lint (``--ast-only``): registry-contract rules over the source
    tree (ast_rules.AST1-AST3).
  * Registry coverage (``--registry-only``): the former
    ``make check-registry`` — every scheme has sane volume/rounds
    functions and a tier-1 parity test (folded in here).
  * HLO sweep (``--hlo-only``): for every executable scheme x {flat,
    hier} x n in {2, 8}, lower a saturating sync program once on the
    host-platform mesh and run the R1-R5 catalog (analysis/rules) over
    the optimized HLO, the StableHLO, and (for the run_schedule subject)
    the jaxpr.  Wire expectations come from the registry's
    ``wire_words_fn`` metadata; a scheme registered without lint
    metadata is itself a finding.

The sweep executes each program too (cheap at these sizes): overflow or
a wrong sum is reported as a DRIVER finding — a lint that certifies
bytes of a numerically wrong program would be theater.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
from typing import Dict, List, Optional, Tuple

from repro.analysis import ast_rules, hlo_ir, rules
from repro.analysis.rules import Finding, Subject, WireExpectation

WORD = 4  # f32/i32 wire word, bytes

DEFAULT_NS = (2, 8)
DEFAULT_M = 4096
SCHED_BUCKETS = 3


def _ensure_host_devices() -> None:
    """Must run before the first jax import in this process."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()


def _shard_map():
    import jax
    try:
        sm = jax.shard_map
        return sm, {"check_vma": False}
    except AttributeError:
        from jax.experimental.shard_map import shard_map as sm
        return sm, {"check_rep": False}


def _payload(M: int, n: int, density: float):
    """Per-worker [n, M] grads: identical support on every worker (claims
    stay device-symmetric), distinct dyadic values (sums are exact)."""
    import numpy as np
    g = np.zeros((n, M), np.float32)
    stride = max(1, int(round(1.0 / density)))
    pos = np.arange(0, M, stride)
    for i in range(n):
        g[i, pos] = 1.0 + i / 8.0 + (pos % 7) / 64.0
    return g


def _stage_setup(spec, M: int, n_level: int, overrides=None):
    """(StageArgs, expected wire words) for one level of size n_level.

    ``overrides`` — ((StageArgs field, value), ...) from a
    ``SchemeSpec.lint_routes`` entry: a compute-route variant (e.g. zen's
    fused-commit megakernel) that must satisfy the SAME wire contract —
    the expectation is computed from the un-overridden kwargs, so a route
    that changes a transmitted word fails R2."""
    from repro.core import registry as sreg
    from repro.core import schemes
    kwargs = dict(spec.lint_caps_fn(M, n_level)) if spec.lint_caps_fn else {}
    args = sreg.StageArgs(**kwargs)
    if "layout" in spec.stage_args:
        layout = schemes.make_zen_layout(
            M, n_level, density_budget=min(1.0, 2 * spec.lint_density))
        args = dataclasses.replace(args, layout=layout)
    kw = sreg.stage_kwargs(spec, args)
    exp_words = (spec.wire_words_fn(M, n_level, kw)
                 if spec.wire_words_fn else None)
    if overrides:
        args = dataclasses.replace(args, **dict(overrides))
    return args, exp_words


def _meta_findings(spec, label: str) -> List[Finding]:
    """A scheme cannot enter the sweep without its wire contract."""
    missing = [f for f, v in (("wire_words_fn", spec.wire_words_fn),
                              ("expected_collectives",
                               spec.expected_collectives)) if not v]
    if not missing:
        return []
    return [Finding(
        "R2", f"scheme {spec.name!r} registered without zenlint metadata "
              f"({', '.join(missing)}) — register the wire contract "
              f"(core/costmodel.py) before it can be certified",
        case=label)]


def _run_and_lower(jfn, g, label: str):
    """Execute + lower once; returns (stats arrays, subject pieces,
    driver findings)."""
    import numpy as np
    findings: List[Finding] = []
    out, words, ov = jfn(g)
    if int(np.asarray(ov).sum()) != 0:
        findings.append(Finding(
            "DRIVER", f"lint payload overflowed a capacity "
                      f"(overflow={int(np.asarray(ov).sum())}) — "
                      f"lint_caps_fn does not saturate exactly",
            case=label))
    ga = np.asarray(g)
    want = ga.reshape(-1, ga.shape[-1]).sum(0)  # sum over all workers
    got = np.asarray(out)
    if not np.allclose(got, want, atol=1e-5):
        findings.append(Finding(
            "DRIVER", f"synced result != sum of workers (max err "
                      f"{float(abs(got - want).max()):.2e})", case=label))
    lowered = jfn.lower(g)
    stablehlo = lowered.as_text()
    hlo = lowered.compile().as_text()
    return np.asarray(words), stablehlo, hlo, findings


def build_flat_subject(
        scheme: str, n: int, M: int, route=None
) -> Tuple[Optional[Subject], List[Finding]]:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.core import registry as sreg
    from repro.core import schemes

    label = f"{scheme} flat n={n}"
    overrides = None
    if route is not None:
        rlabel, overrides = route
        label = f"{label} [{rlabel}]"
    spec = sreg.get_scheme(scheme)
    findings = _meta_findings(spec, label)
    if findings:
        return None, findings
    args, exp_words = _stage_setup(spec, M, n, overrides=overrides)
    sm, smkw = _shard_map()
    mesh = Mesh(np.asarray(jax.devices()[:n]), ("data",))

    def local(v):
        out, st = schemes.stage_sync(scheme, v[0], axis="data", n=n,
                                     stage_args=args)
        return out, st.sent_words[None], st.overflow[None]

    mapped = sm(local, mesh=mesh, in_specs=P("data"),
                out_specs=(P(), P("data"), P("data")), **smkw)
    g = jnp.asarray(_payload(M, n, spec.lint_density))
    words, stablehlo, hlo, findings = _run_and_lower(
        jax.jit(mapped), g, label)
    claimed = float(words.reshape(-1).max()) * WORD
    subject = Subject(
        label=label,
        module=hlo_ir.HloModule.parse(hlo),
        stablehlo_text=stablehlo,
        wire={n: WireExpectation(
            expected_bytes=exp_words * WORD, claimed_bytes=claimed,
            kinds=spec.expected_collectives,
            claim_exact=spec.lint_saturable)},
        exempt=spec.lint_exempt)
    return subject, findings


def build_hier_subject(
        scheme: str, n: int, M: int, node_size: int = 2
) -> Tuple[Optional[Subject], List[Finding]]:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.core import registry as sreg
    from repro.core import schemes
    from repro.core import topology as tp

    label = f"hier({scheme}@intra,{scheme}@inter) n={n} node={node_size}"
    spec = sreg.get_scheme(scheme)
    findings = _meta_findings(spec, label)
    if findings:
        return None, findings
    topo = tp.build_topology(n, node_size)
    plan = tp.hier_plan(scheme, scheme)
    stage_kw, wire = {}, {}
    for li, lvl in enumerate(topo.levels):
        if lvl.size <= 1:
            continue
        if not spec.feasible(lvl.size, M):
            return None, []  # this scheme cannot run at this level size
        args, exp_words = _stage_setup(spec, M, lvl.size)
        stage_kw[li] = args
        wire[lvl.size] = exp_words  # group sizes distinct (2 vs n//2)
    n_intra, n_inter = topo.intra.size, topo.inter.size
    sm, smkw = _shard_map()
    mesh = Mesh(np.asarray(jax.devices()[:n]).reshape(n_inter, n_intra),
                (tp.DP_INTER, tp.DP_INTRA))

    def local(v):
        out, st = schemes.hier_sync(v[0, 0], topology=topo, plan=plan,
                                    stage_kw=stage_kw)
        lv = jnp.stack(list(st.by_level))
        return out, lv[None, None], st.overflow[None, None]

    spec2 = P(tp.DP_INTER, tp.DP_INTRA)
    mapped = sm(local, mesh=mesh, in_specs=spec2,
                out_specs=(P(), spec2, spec2), **smkw)
    g = jnp.asarray(_payload(M, n, spec.lint_density)
                    ).reshape(n_inter, n_intra, M)
    by_level, stablehlo, hlo, findings = _run_and_lower(
        jax.jit(mapped), g, label)
    # by_level: [n_inter, n_intra, n_levels] -> claimed words per level
    by_level = by_level.reshape(-1, len(topo.levels))
    expectations: Dict[int, WireExpectation] = {}
    for li, lvl in enumerate(topo.levels):
        if lvl.size not in wire:
            continue
        expectations[lvl.size] = WireExpectation(
            expected_bytes=wire[lvl.size] * WORD,
            claimed_bytes=float(by_level[:, li].max()) * WORD,
            kinds=spec.expected_collectives,
            claim_exact=spec.lint_saturable)
    subject = Subject(
        label=label,
        module=hlo_ir.HloModule.parse(hlo),
        stablehlo_text=stablehlo,
        wire=expectations,
        exempt=spec.lint_exempt)
    return subject, findings


def build_schedule_subject(
        n: int = 8, M: int = 2048, nb: int = SCHED_BUCKETS
) -> Tuple[Subject, List[Finding]]:
    """The run_schedule overlap pipeline as a lint subject (R4).

    A flat zen pipeline over ``nb`` buckets: encode is collective-free,
    so every optimization_barrier input must be independent of any
    collective — the double-buffering contract (train/schedule.py)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.core import buckets as bk
    from repro.core import schemes
    from repro.train import schedule

    label = f"run_schedule zen nb={nb} flat n={n}"
    density = 0.25
    layout = schemes.make_zen_layout(M, n, density_budget=2 * density)
    bucks = [bk.Bucket(bid=i, kind=bk.DENSE, scheme="zen",
                       slots=(bk.LeafSlot(f"w{i}", i, (M,), jnp.float32,
                                          0, M),),
                       nbytes=M * WORD)
             for i in range(nb)]
    sm, smkw = _shard_map()
    mesh = Mesh(np.asarray(jax.devices()[:n]), ("data",))

    def local(v):  # [1, nb, M]
        payloads = [v[0, i] for i in range(nb)]

        def encode(b, p):
            return (p, schemes.zen_encode(p, layout=layout))

        def commit(b, pe):
            p, enc = pe
            return schemes.zen_commit(enc, p, axis="data", layout=layout)

        outs, stats = schedule.run_schedule(bucks, payloads, encode, commit)
        words = sum(st.sent_words for st in stats)
        ov = sum(st.overflow for st in stats)
        return jnp.stack(outs), words[None], ov[None]

    mapped = sm(local, mesh=mesh, in_specs=P("data"),
                out_specs=(P(), P("data"), P("data")), **smkw)
    base = _payload(M, n, density)
    g = jnp.asarray(np.stack([base * (1 + b / 16.0) for b in range(nb)],
                             axis=1))  # [n, nb, M]
    jfn = jax.jit(mapped)
    findings: List[Finding] = []
    out, words, ov = jfn(g)
    if int(np.asarray(ov).sum()) != 0:
        findings.append(Finding("DRIVER", "schedule payload overflowed",
                                case=label))
    want = np.asarray(g).sum(0)
    if not np.allclose(np.asarray(out), want, atol=1e-4):
        findings.append(Finding("DRIVER",
                                "scheduled sync != sum of workers",
                                case=label))
    lowered = jfn.lower(g)
    subject = Subject(
        label=label,
        module=hlo_ir.HloModule.parse(lowered.compile().as_text()),
        stablehlo_text=lowered.as_text(),
        jaxpr=jax.make_jaxpr(mapped)(g),
        expected_fences=nb - 1,
        fences_collective_free=True)
    return subject, findings


def run_hlo_sweep(schemes_filter: Optional[List[str]] = None,
                  ns: Tuple[int, ...] = DEFAULT_NS,
                  M: int = DEFAULT_M,
                  with_schedule: bool = True,
                  verbose: bool = True) -> List[Finding]:
    from repro.core import registry as sreg

    findings: List[Finding] = []
    names = sreg.registered_schemes(executable_only=True)
    if schemes_filter:
        unknown = sorted(set(schemes_filter) - set(names))
        if unknown:
            raise SystemExit(f"unknown scheme(s): {', '.join(unknown)} "
                             f"(executable: {', '.join(names)})")
        names = tuple(s for s in names if s in schemes_filter)
    for scheme in names:
        spec = sreg.get_scheme(scheme)
        for waived in spec.lint_exempt:
            print(f"  WAIVED {scheme}: rule {waived} "
                  f"(SchemeSpec.lint_exempt)")
        for n in ns:
            for build, kind in ((build_flat_subject, "flat"),
                                (build_hier_subject, "hier")):
                if kind == "flat" and not spec.feasible(n, M):
                    continue
                subject, extra = build(scheme, n, M)
                findings.extend(extra)
                if subject is None:
                    continue
                got = rules.run_rules(subject)
                findings.extend(got)
                if verbose:
                    status = ("ok" if not (got or extra)
                              else f"{len(got) + len(extra)} finding(s)")
                    print(f"  {subject.label}: {status}")
            # compute-route variants (SchemeSpec.lint_routes): same R1-R5
            # catalog, same wire contract — a fused route that changed a
            # single transmitted word fails here
            for route in spec.lint_routes:
                if not spec.feasible(n, M):
                    continue
                subject, extra = build_flat_subject(scheme, n, M,
                                                    route=route)
                findings.extend(extra)
                if subject is None:
                    continue
                got = rules.run_rules(subject)
                findings.extend(got)
                if verbose:
                    status = ("ok" if not (got or extra)
                              else f"{len(got) + len(extra)} finding(s)")
                    print(f"  {subject.label}: {status}")
    want_sched = (not schemes_filter
                  or "zen" in schemes_filter)  # zenlint: ignore[AST2]
    if with_schedule and want_sched:
        subject, extra = build_schedule_subject()
        got = rules.run_rules(subject)
        findings.extend(extra + got)
        if verbose:
            status = "ok" if not (got or extra) else \
                f"{len(got) + len(extra)} finding(s)"
            print(f"  {subject.label}: {status}")
    return findings


def registry_findings(tests_dir: str = "tests") -> List[Finding]:
    from repro.core import registry as sreg
    return [Finding("REG", e, case="registry coverage")
            for e in sreg.coverage_errors(tests_dir)]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis.lint",
        description="zenlint: certify every registered scheme's lowered "
                    "program against the R1-R5 invariant catalog, the "
                    "registry contract (AST), and registry coverage.")
    layer = ap.add_mutually_exclusive_group()
    layer.add_argument("--ast-only", action="store_true",
                       help="source-tree registry-contract lint only")
    layer.add_argument("--hlo-only", action="store_true",
                       help="HLO sweep (R1-R5) only")
    layer.add_argument("--registry-only", action="store_true",
                       help="registry-coverage check only (the former "
                            "`make check-registry`)")
    ap.add_argument("--schemes", default=None,
                    help="comma-separated scheme filter for the sweep")
    ap.add_argument("--ns", default=",".join(map(str, DEFAULT_NS)),
                    help="comma-separated worker counts (default 2,8)")
    ap.add_argument("--m", type=int, default=DEFAULT_M,
                    help=f"payload length (default {DEFAULT_M})")
    ap.add_argument("--tree", default="src/repro",
                    help="root for the AST layer")
    ap.add_argument("--tests-dir", default="tests",
                    help="tier-1 test dir for registry coverage")
    args = ap.parse_args(argv)

    do_ast = args.ast_only or not (args.hlo_only or args.registry_only)
    do_reg = args.registry_only or not (args.ast_only or args.hlo_only)
    do_hlo = args.hlo_only or not (args.ast_only or args.registry_only)

    findings: List[Finding] = []
    if do_ast:
        print(f"zenlint: AST rules over {args.tree}")
        findings.extend(ast_rules.run_tree(args.tree))
    if do_reg:
        print("zenlint: registry coverage")
        findings.extend(registry_findings(args.tests_dir))
    if do_hlo:
        _ensure_host_devices()
        ns = tuple(int(x) for x in args.ns.split(",") if x)
        flt = (args.schemes.split(",") if args.schemes else None)
        print(f"zenlint: HLO sweep (R1-R5), n in {ns}, M={args.m}")
        findings.extend(run_hlo_sweep(flt, ns, args.m))

    for f in findings:
        print(f"FINDING {f}")
    n_rules = len(rules.RULES)
    print(f"zenlint: {len(findings)} finding(s) "
          f"[{n_rules} HLO rules, 3 AST rules] — "
          f"{'FAIL' if findings else 'ok'}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())

"""Executable communication schemes for sparse tensor synchronization (§2.3).

Every scheme is an SPMD function of the *local* dense gradient, written
against ``jax.lax`` collectives with a named axis.  The same code runs:

* under ``jax.vmap(..., axis_name=AXIS)`` — single-device simulation used by
  unit/property tests and traffic accounting;
* under ``jax.shard_map`` over a real mesh axis — used by the trainer and the
  multi-pod dry-run.

Static-shape discipline (DESIGN.md §3): sparse buffers have fixed capacities.
A scheme's capacity requirement *is* its traffic claim — imbalanced schemes
(Sparse PS, OmniReduce) must provision ``skew × nnz/n`` per partition where
balanced ones provision ``nnz/n``; overflow counters surface under-provisioning
instead of silently corrupting gradients.

Schemes (Table 2, plus the Ok-Topk family):
  dense_sync        Ring + incremental + parallelism + balanced (psum).
  agsparse_sync     AllGather of COO (one-shot, centralization).
  sparcml_sync      SSAR recursive-doubling, incremental, centralization.
  sparse_ps_sync    P2P + one-shot + parallelism, even-range partition
                    (imbalanced).
  omnireduce_sync   As Sparse PS but with the tensor-block format.
  balanced_sync     Ok-Topk-style load-balanced split-and-exchange: a
                    histogram rebalance sizes the index ranges so the
                    per-worker receive volume is O(nnz_global/n + bins)
                    regardless of skew (arXiv 2201.07598).
  zen_sync          Balanced Parallelism via hierarchical hashing + hash
                    bitmap — the paper's contribution.

Dispatch is by the scheme registry (``repro.core.registry``): every
scheme registers its executable, volume/round formulas, and typed
``StageArgs`` exactly once (at the bottom of ``core/costmodel.py``);
``stage_sync`` and the planner both read that single record.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import formats
from repro.core import registry as sreg
from repro.core.registry import BALANCED_BINS, StageArgs
from repro.core.hashing import (
    EMPTY,
    compact_indices,
    compact_rows,
    extract_partitions,
    hash_mod,
    hierarchical_hash,
    make_seeds,
)


class SyncStats(NamedTuple):
    """Per-worker accounting: wire words sent and capacity overflows.

    ``by_level`` tags wire words by topology level for hierarchical plans
    (fastest level first — ``(intra_words, inter_words)`` for a two-level
    plan); flat schemes leave it empty, meaning "all words at level 0".
    """

    sent_words: jnp.ndarray  # f32 scalar
    overflow: jnp.ndarray    # i32 scalar (total dropped non-zeros)
    by_level: tuple = ()     # per-level f32 wire words (hier plans only)


def _axis_size(axis: str) -> int:
    """Size of a named axis as a static python int — axis sizing must
    never emit a collective.

    ``lax.axis_size`` (newer jax) is the public spelling.  On the pinned
    0.4.x CI leg it does not exist; there ``jax.core.axis_frame(axis)``
    resolves the size from the trace-time axis env (returning either the
    int itself or a frame carrying ``.size``, depending on the release).
    ``psum(1, axis)`` stays as the last-resort fallback — jax folds a
    non-tracer operand statically, so even that path is collective-free,
    which tests/test_hier_schemes.py asserts on lowered HLO."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis)
    try:
        frame = jax.core.axis_frame(axis)
        return int(getattr(frame, "size", frame))
    except Exception:
        return lax.psum(1, axis)


def _nnz(idx: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum((idx != EMPTY).astype(jnp.float32))


def _vwidth(dense: jnp.ndarray) -> int:
    """Words per value: 1 for element-sparse, d for row-sparse."""
    return 1 if dense.ndim == 1 else dense.shape[-1]


def _mask(dense: jnp.ndarray) -> jnp.ndarray:
    return dense != 0 if dense.ndim == 1 else jnp.any(dense != 0, axis=-1)


def _gather_rows(dense: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Gather dense[idx] with EMPTY -> 0; idx may have any leading shape."""
    safe = jnp.where(idx == EMPTY, 0, idx)
    vals = dense[safe]
    dead = (idx == EMPTY) if dense.ndim == 1 else (idx == EMPTY)[..., None]
    return jnp.where(dead, 0, vals)


def _scatter_add(
    out: jnp.ndarray, idx: jnp.ndarray, vals: jnp.ndarray, *, offset=0
) -> jnp.ndarray:
    tgt = jnp.where(idx == EMPTY, out.shape[0], idx - offset)
    return out.at[tgt].add(vals, mode="drop")


def _scatter_unique(
    out: jnp.ndarray, idx: jnp.ndarray, vals: jnp.ndarray
) -> jnp.ndarray:
    """Collision-free scatter for provably disjoint targets (Thm. 2).

    Zen's pull decode recovers ``perm[offsets[p] + local_pos]`` — servers
    own non-overlapping index ranges (``offsets`` partitions ``[0, M)``)
    and positions within a range are unique, so no two live updates share
    a target.  ``.at[].set`` then equals add-into-zeros value-for-value
    (0 + v == v; only the sign of a -0.0 value could differ, which the
    wire contract treats as equal) while telling XLA the scatter needs no
    combiner."""
    tgt = jnp.where(idx == EMPTY, out.shape[0], idx)
    return out.at[tgt].set(vals, mode="drop")


def _coo_reduce(
    out: jnp.ndarray, idx: jnp.ndarray, vals: jnp.ndarray,
    *, backend: str = "xla", interpret: bool | None = None,
) -> jnp.ndarray:
    """The one batched segment-reduce every scheme's server aggregation
    uses: out [M(, d)] += vals at row idx, EMPTY / out-of-range dropped.
    Thin shim over ``kernels.ops.batched_coo_reduce_op`` (which owns the
    flatten + backend dispatch); idx/vals may carry any leading shape."""
    from repro.kernels import ops as kops  # deferred: kernels import core

    return kops.batched_coo_reduce_op(out, idx, vals, backend=backend,
                                      interpret=interpret)


# ---------------------------------------------------------------------------
# Dense baseline
# ---------------------------------------------------------------------------

def dense_sync(dense: jnp.ndarray, *, axis: str) -> tuple[jnp.ndarray, SyncStats]:
    """Ring allreduce (Horovod's AllReduce in the paper's evaluation)."""
    n = _axis_size(axis)
    out = lax.psum(dense, axis)
    words = jnp.float32(2 * (n - 1) / n) * dense.size
    return out, SyncStats(sent_words=words, overflow=jnp.int32(0))


# ---------------------------------------------------------------------------
# AGsparse
# ---------------------------------------------------------------------------

def agsparse_sync(
    dense: jnp.ndarray, *, axis: str, capacity: int
) -> tuple[jnp.ndarray, SyncStats]:
    """AllGather of fixed-capacity COO; every GPU aggregates everything."""
    coo = formats.coo_encode(dense, capacity)
    all_idx = lax.all_gather(coo.indices, axis)   # [n, C]
    all_val = lax.all_gather(coo.values, axis)    # [n, C(, d)]
    out = _coo_reduce(jnp.zeros_like(dense), all_idx, all_val)
    n = _axis_size(axis)
    sent = (n - 1) * _nnz(coo.indices) * (1 + _vwidth(dense))
    return out, SyncStats(sent_words=sent, overflow=coo.overflow)


# ---------------------------------------------------------------------------
# SparCML (SSAR_Recursive_double)
# ---------------------------------------------------------------------------

def sparcml_sync(
    dense: jnp.ndarray, *, axis: str, n: int, capacity: int
) -> tuple[jnp.ndarray, SyncStats]:
    """Recursive doubling with incremental aggregation and COO exchange.

    Stage s pairs rank with rank XOR 2^s; the exchanged set doubles in the
    worst case each stage (densification makes it sub-double in practice), so
    stage capacity is ``min(capacity * 2^s, M)``.
    """
    if n <= 0 or n & (n - 1) != 0:
        raise ValueError(
            f"sparcml_sync: recursive doubling needs a power-of-two worker "
            f"count, got n={n}. Pad the data-parallel axis to the next power "
            f"of two, or pick scheme='zen', which accepts any n.")
    acc = dense
    sent = jnp.float32(0)
    overflow = jnp.int32(0)
    vw = _vwidth(dense)
    for s in range(int(math.log2(n))):
        cap_s = min(capacity * (2 ** s) * 2, dense.shape[0])
        coo = formats.coo_encode(acc, cap_s)
        perm = [(i, i ^ (1 << s)) for i in range(n)]
        got_idx = lax.ppermute(coo.indices, axis, perm)
        got_val = lax.ppermute(coo.values, axis, perm)
        acc = _scatter_add(acc, got_idx, got_val)
        sent = sent + _nnz(coo.indices) * (1 + vw)
        overflow = overflow + coo.overflow
    return acc, SyncStats(sent_words=sent, overflow=overflow)


# ---------------------------------------------------------------------------
# Sparse PS (even-range partitioning — the imbalanced strawman)
# ---------------------------------------------------------------------------

def sparse_ps_sync(
    dense: jnp.ndarray, *, axis: str, n: int, cap_push: int, cap_pull: int
) -> tuple[jnp.ndarray, SyncStats]:
    """P2P + one-shot + parallelism with *even contiguous* partitions.

    Each device doubles as worker and server ``rank``.  Because the partition
    is positional, C3 skew concentrates non-zeros in few partitions: correct
    provisioning needs ``cap_push ≈ skew × nnz / n`` — the imbalance cost.
    """
    M = dense.shape[0]
    if M % n != 0:
        raise ValueError(
            f"sparse_ps_sync: even-range partitioning needs the tensor length "
            f"to divide by the worker count, got M={M}, n={n} "
            f"(M % n = {M % n}). Pad the tensor to "
            f"{(M + n - 1) // n * n} rows or use scheme='zen', whose hash "
            f"partitioning has no divisibility requirement.")
    shard = M // n
    vw = _vwidth(dense)
    # --- Push: split into n contiguous ranges, COO-encode each --------------
    parts = dense.reshape(n, shard, *dense.shape[1:])
    coo = jax.vmap(lambda d: formats.coo_encode(d, cap_push))(parts)
    # indices are local to the range; a2a delivers partition r to rank r
    got_idx = lax.all_to_all(coo.indices, axis, split_axis=0, concat_axis=0)
    got_val = lax.all_to_all(coo.values, axis, split_axis=0, concat_axis=0)
    # --- Server aggregation --------------------------------------------------
    buf = _coo_reduce(jnp.zeros((shard, *dense.shape[1:]), dense.dtype),
                      got_idx, got_val)
    # --- Pull: COO of the aggregated shard, all_gather -----------------------
    pull = formats.coo_encode(buf, cap_pull)
    all_idx = lax.all_gather(pull.indices, axis)  # [n, cap_pull]
    all_val = lax.all_gather(pull.values, axis)
    rank_off = (jnp.arange(n, dtype=jnp.int32) * shard)[:, None]
    glob = jnp.where(all_idx == EMPTY, EMPTY, all_idx + rank_off)
    out = _coo_reduce(jnp.zeros_like(dense), glob, all_val)
    sent = (jnp.sum(jax.vmap(_nnz)(coo.indices)) - _nnz(coo.indices[lax.axis_index(axis)])
            + (n - 1) * _nnz(pull.indices)) * (1 + vw)
    overflow = jnp.sum(coo.overflow) + pull.overflow
    return out, SyncStats(sent_words=sent, overflow=overflow)


# ---------------------------------------------------------------------------
# OmniReduce (tensor-block format, even-range partitioning)
# ---------------------------------------------------------------------------

def omnireduce_sync(
    dense: jnp.ndarray, *, axis: str, n: int, block: int,
    cap_push: int, cap_pull: int,
) -> tuple[jnp.ndarray, SyncStats]:
    """As Sparse PS but transmitting non-zero *blocks* (no per-element index).
    """
    M = dense.shape[0]
    if M % n != 0 or (M // n) % block != 0:
        raise ValueError(
            f"omnireduce_sync: needs M divisible by n*block so every worker's "
            f"contiguous range is a whole number of blocks, got M={M}, n={n}, "
            f"block={block}. Pad the tensor to "
            f"{(M + n * block - 1) // (n * block) * (n * block)} rows, shrink "
            f"`block`, or use scheme='zen' (no divisibility requirement).")
    shard = M // n
    parts = dense.reshape(n, shard, *dense.shape[1:])
    blk = jax.vmap(lambda d: formats.blocks_encode(d, block, cap_push))(parts)
    got_ids = lax.all_to_all(blk.block_ids, axis, split_axis=0, concat_axis=0)
    got_val = lax.all_to_all(blk.values, axis, split_axis=0, concat_axis=0)
    nb = shard // block
    buf = jnp.zeros((nb, block, *dense.shape[1:]), dense.dtype)
    tgt = jnp.where(got_ids == EMPTY, nb, got_ids).reshape(-1)
    buf = buf.at[tgt].add(got_val.reshape(-1, *got_val.shape[2:]), mode="drop")
    buf = buf.reshape(shard, *dense.shape[1:])
    pull = formats.blocks_encode(buf, block, cap_pull)
    all_ids = lax.all_gather(pull.block_ids, axis)
    all_val = lax.all_gather(pull.values, axis)
    rank_off = (jnp.arange(n, dtype=jnp.int32) * nb)[:, None]
    glob = jnp.where(all_ids == EMPTY, EMPTY, all_ids + rank_off)
    out_b = jnp.zeros((M // block, block, *dense.shape[1:]), dense.dtype)
    tgt = jnp.where(glob == EMPTY, M // block, glob).reshape(-1)
    out_b = out_b.at[tgt].add(all_val.reshape(-1, *all_val.shape[2:]),
                              mode="drop")
    out = out_b.reshape(M, *dense.shape[1:])
    vw = _vwidth(dense)
    wpb = block * vw + 1  # words per block on the wire (values + id)
    sent = (jnp.sum(jax.vmap(lambda i: _nnz(i))(blk.block_ids))
            - _nnz(blk.block_ids[lax.axis_index(axis)])
            + (n - 1) * _nnz(pull.block_ids)) * wpb
    overflow = jnp.sum(blk.overflow) + pull.overflow
    return out, SyncStats(sent_words=sent, overflow=overflow)


# ---------------------------------------------------------------------------
# Balanced split-and-exchange (Ok-Topk family, arXiv 2201.07598)
# ---------------------------------------------------------------------------

def balanced_sync(
    dense: jnp.ndarray, *, axis: str, n: int, cap_push: int,
    cap_pull: int | None = None, bins: int | None = None,
) -> tuple[jnp.ndarray, SyncStats]:
    """Load-balanced split-and-exchange allreduce.

    Where ``sparse_ps_sync`` partitions the index space into *even*
    contiguous ranges (so skewed nonzeros concentrate on few servers and
    correct provisioning costs ``skew x nnz/n`` — O(n·nnz_max) at full
    skew), this scheme *rebalances* the range boundaries per step:

    1. Compact local nonzero indices (budget ``cap_push``).
    2. Build a ``min(M, bins)``-bin equal-width histogram of the global
       nonzero multiset — one f32 allreduce of the local histograms.
    3. Assign contiguous bin ranges to destinations by the exclusive
       cumulative count: ``dest(j) = floor(cum(j) * n / total)``.  Every
       destination's range then holds at most ``total/n + max_bin``
       multiset entries — the balanced receive bound O(nnz_global/n +
       bin granularity), independent of skew.
    4. Split local nonzeros by destination, ``all_to_all`` the COO
       (global indices — no per-range offset bookkeeping), scatter-add
       into a length-M buffer, compact the aggregated range
       (``cap_pull``, default ``cap_push``), ``all_gather`` the reduced
       shards.

    Unlike zen there is no precomputed layout: the partition is a pure
    function of this step's histogram, so MoE-style routing shifts are
    absorbed step by step at the price of the histogram allreduce
    (``2 (n-1)/n * bins`` words, charged to ``sent_words``).
    """
    M = dense.shape[0]
    if cap_pull is None:
        cap_pull = cap_push
    B = min(M, bins or BALANCED_BINS)
    bw = -(-M // B)  # bin width (ceil), last bin may be ragged
    vw = _vwidth(dense)
    my_rank = lax.axis_index(axis)

    # --- 1. local compaction -------------------------------------------------
    # total sendable budget is n * cap_push (cap_push slots per
    # destination); the split below redistributes, it cannot grow
    cap_local = n * cap_push
    idx, ov_c = compact_indices(_mask(dense), cap_local)
    live = idx != EMPTY
    bin_of = jnp.where(live, jnp.where(live, idx, 0) // bw, B)

    # --- 2. global multiset histogram (f32 allreduce: counts < 2^24 exact) ---
    local_hist = jnp.zeros((B,), jnp.float32).at[bin_of].add(1.0, mode="drop")
    hist = lax.psum(local_hist, axis)
    hist_words = jnp.float32(2 * (n - 1) / n) * B

    # --- 3. balanced contiguous bin -> destination assignment ----------------
    cum = jnp.cumsum(hist)
    total = jnp.maximum(cum[-1], 1.0)
    excl = cum - hist                     # exclusive prefix counts
    dest_of_bin = jnp.clip(
        (excl * n / total).astype(jnp.int32), 0, n - 1)
    dest = jnp.where(live, dest_of_bin[jnp.clip(bin_of, 0, B - 1)], n)

    # --- 4. per-destination split + exchange ---------------------------------
    member = dest[None, :] == jnp.arange(n, dtype=jnp.int32)[:, None]
    lpos, ov_s = compact_rows(member, cap_push)           # [n, cap_push]
    pidx = jnp.where(lpos == EMPTY, EMPTY,
                     idx[jnp.clip(lpos, 0, cap_local - 1)])
    pval = _gather_rows(dense, pidx)
    got_idx = lax.all_to_all(pidx, axis, split_axis=0, concat_axis=0)
    got_val = lax.all_to_all(pval, axis, split_axis=0, concat_axis=0)

    # --- server aggregation over the full index space (global indices) -------
    buf = _coo_reduce(jnp.zeros_like(dense), got_idx, got_val)

    # --- pull: compact the aggregated range, allgather the reduced shards ----
    pull_idx, ov_p = compact_indices(_mask(buf), cap_pull)
    pull_val = _gather_rows(buf, pull_idx)
    all_idx = lax.all_gather(pull_idx, axis)              # [n, cap_pull]
    all_val = lax.all_gather(pull_val, axis)
    out = _coo_reduce(jnp.zeros_like(dense), all_idx, all_val)

    nnz_per_dest = jnp.sum(pidx != EMPTY, axis=1).astype(jnp.float32)
    push_sent = (jnp.sum(nnz_per_dest) - nnz_per_dest[my_rank]) * (1 + vw)
    pull_sent = (n - 1) * _nnz(pull_idx) * (1 + vw)
    stats = SyncStats(
        sent_words=push_sent + pull_sent + hist_words,
        overflow=ov_c + jnp.sum(ov_s) + ov_p,
    )
    return out, stats


# ---------------------------------------------------------------------------
# Zen: Balanced Parallelism via hierarchical hashing + hash bitmap
# ---------------------------------------------------------------------------

class _DeviceTables(NamedTuple):
    """ZenLayout's lookup tables as device-resident arrays (uploaded once)."""

    seeds: jnp.ndarray       # uint32 [k+1]
    perm: jnp.ndarray        # int32 [M]
    local_pos: jnp.ndarray   # int32 [M]
    offsets: jnp.ndarray     # int32 [n+1]


@dataclasses.dataclass(frozen=True)
class ZenLayout:
    """Offline-precomputed, worker-shared state for one tensor shape.

    Built once per (tensor length, n, h0 seed) — the paper broadcasts the
    hash seeds at job start; everything here is a pure function of those.
    """

    n: int
    length: int
    seeds: np.ndarray          # uint32 [k+1]
    perm: np.ndarray           # int32 [M]   (I_0 .. I_{n-1} concatenated)
    offsets: np.ndarray        # int32 [n+1]
    local_pos: np.ndarray      # int32 [M]   global idx -> rank inside its I_p
    cap_server: int            # max_i |I_i| (static server buffer size)
    # Alg. 1 capacities
    cap_index: int             # C: worker-side nnz budget
    r1: int
    r2: int
    k: int

    @property
    def cap_bitmap_words(self) -> int:
        return (self.cap_server + 31) // 32

    def device_tables(self) -> _DeviceTables:
        """The numpy tables as device arrays, uploaded on first use and cached
        on the layout — repeated traces of ``zen_sync`` reuse the same buffers
        instead of re-staging ~2M ints of constants per trace."""
        tabs = self.__dict__.get("_device_tables")
        if tabs is None:
            # the first call may happen inside a jit trace: force eager
            # upload so concrete arrays (not tracers) are cached
            with jax.ensure_compile_time_eval():
                tabs = _DeviceTables(
                    seeds=jnp.asarray(self.seeds, dtype=jnp.uint32),
                    perm=jnp.asarray(self.perm, dtype=jnp.int32),
                    local_pos=jnp.asarray(self.local_pos, dtype=jnp.int32),
                    offsets=jnp.asarray(self.offsets, dtype=jnp.int32),
                )
            object.__setattr__(self, "_device_tables", tabs)
        return tabs

    def static_seeds(self) -> tuple:
        """Seeds as compile-time python ints (the pallas hash kernel bakes
        them in, mirroring the paper's broadcast-at-startup)."""
        return tuple(int(s) for s in np.asarray(self.seeds))


def make_zen_layout(
    length: int,
    n: int,
    *,
    density_budget: float,
    key: int = 0,
    k: int = 3,
    r1_factor: float = 2.0,
    r2_ratio: float = 0.1,
) -> ZenLayout:
    """Precompute the Zen layout (offline; numpy, not traced).

    ``density_budget`` is the max per-worker density the buffers are sized
    for (the paper sizes r1 = 2 |G| d_G).  Per-partition parallel memory is
    ``r1 = r1_factor * C / n`` and serial memory ``r2 = r2_ratio * r1``.
    """
    seeds = np.asarray(make_seeds(key, k + 1))
    idx = np.arange(length, dtype=np.int64)
    p = np.asarray(hash_mod(jnp.asarray(idx, jnp.int32), seeds[0], n))
    order = np.argsort(p, kind="stable").astype(np.int32)
    counts = np.bincount(p, minlength=n)
    offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    local = np.empty(length, dtype=np.int32)
    local[order] = np.arange(length, dtype=np.int32) - offsets[p[order]]
    cap_index = max(32, int(math.ceil(length * density_budget)))
    r1 = max(8, int(math.ceil(r1_factor * cap_index / n)))
    r2 = max(4, int(math.ceil(r2_ratio * r1)))
    return ZenLayout(
        n=n, length=length, seeds=seeds, perm=order,
        offsets=offsets, local_pos=local,
        cap_server=int(counts.max()), cap_index=cap_index,
        r1=r1, r2=r2, k=k,
    )


class ZenEncoded(NamedTuple):
    """Output of ``zen_encode`` — everything the push collective needs."""

    pidx: jnp.ndarray      # int32 [n, r1+r2] partitioned indices
    pval: jnp.ndarray      # [n, r1+r2(, d)] gathered values
    overflow: jnp.ndarray  # i32: worker compaction + serial-memory overflow


def _resolve_backend(backend: str, interpret: bool | None) -> bool:
    if backend not in ("xla", "pallas"):
        raise ValueError(f"backend must be 'xla' or 'pallas', got {backend!r}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return interpret


def zen_encode(
    dense: jnp.ndarray, *, layout: ZenLayout, backend: str = "xla",
    interpret: bool | None = None, fused: bool | None = None,
) -> ZenEncoded:
    """Zen stage 1: local sparsify + hierarchical hash + partition extract.

    Collective-free — this is the compute the bucketed schedule overlaps
    with the previous bucket's wire time (repro.train.schedule).

    ``fused`` (pallas backend only; default on) routes hash + insertion
    rounds + extraction through the single-dispatch megakernel
    (``kernels/zen_encode.py``, DESIGN.md §11) instead of the 3-dispatch
    chain; both are bit-exact vs the XLA path (CI kernel-parity job).
    """
    lo = layout
    n = lo.n
    interpret = _resolve_backend(backend, interpret)
    tabs = lo.device_tables()
    idx, ov_c = compact_indices(_mask(dense), lo.cap_index)
    if backend == "pallas":
        if fused is None or fused:
            from repro.kernels import ops  # deferred: kernels import schemes' deps

            pidx, _occ, ovf = ops.zen_encode_fused_op(
                idx, lo.static_seeds(), n, lo.r1, lo.r2,
                interpret=interpret)
            pval = _gather_rows(dense, pidx)
            return ZenEncoded(pidx=pidx, pval=pval, overflow=ov_c + ovf)
        part = hierarchical_hash(
            idx, n=n, r1=lo.r1, r2=lo.r2, k=lo.k, backend="pallas",
            interpret=interpret, static_seeds=lo.static_seeds())
    else:
        part = hierarchical_hash(
            idx, n=n, r1=lo.r1, r2=lo.r2, k=lo.k, seeds=tabs.seeds)
    pidx = extract_partitions(part, backend=backend, interpret=interpret)
    pval = _gather_rows(dense, pidx)             # [n, r1+r2(, d)]
    return ZenEncoded(pidx=pidx, pval=pval, overflow=ov_c + part.overflow)


def zen_commit(
    enc: ZenEncoded, dense: jnp.ndarray, *, axis: str, layout: ZenLayout,
    use_hash_bitmap: bool = True, backend: str = "xla",
    interpret: bool | None = None, fused: bool | None = None,
) -> tuple[jnp.ndarray, SyncStats]:
    """Zen stages 2-4: push all_to_all, server aggregation, bitmap pull.

    ``dense`` supplies only the output shape/dtype (no data dependency —
    every transmitted value already lives in ``enc``).

    Push, aggregate, and pull all run over ``axis``: a named axis and its
    ``layout`` (sized ``layout.n == axis size``) are one unit.  In a
    hierarchical CommPlan each *stage* brings its own (axis, layout)
    pair, which is how a plan's pull ends up on a different axis than an
    earlier stage's push — there is no valid cross-axis pull *within*
    one zen instance (another axis names a different worker set, whose
    servers hold different partitions).

    ``fused`` (pallas backend only; default on) routes the server-side
    work through the commit megakernel pair (``kernels/zen_commit.py``,
    DESIGN.md §14): aggregation + mask/compact + value gather + bitmap
    pack in one push dispatch, and the batched pull decode (unpack +
    compact_rows) in one pull dispatch.  Wire words and every transmitted
    payload are bit-identical to the unfused chain (zenlint R2 sweeps the
    fused route)."""
    lo = layout
    n = lo.n
    vw = _vwidth(dense)
    interpret = _resolve_backend(backend, interpret)
    fuse = backend == "pallas" and (fused is None or fused)
    tabs = lo.device_tables()
    pidx, pval = enc.pidx, enc.pval

    # --- 2. Push (balanced all_to_all) ---------------------------------------
    got_idx = lax.all_to_all(pidx, axis, split_axis=0, concat_axis=0)
    got_val = lax.all_to_all(pval, axis, split_axis=0, concat_axis=0)

    # --- 3+4a. server aggregation + pull-payload build -----------------------
    flat_idx = got_idx.reshape(-1)
    lp = jnp.where(flat_idx == EMPTY, lo.cap_server,
                   tabs.local_pos[jnp.where(flat_idx == EMPTY, 0, flat_idx)])
    got_v = got_val.reshape(-1, *dense.shape[1:])
    cap_pull = lo.r1 + lo.r2  # aggregated nnz per server <= sum of pushes
    if fuse:
        from repro.kernels import ops as kops  # deferred: kernels import core

        lpos, vals, bm, ov_p = kops.zen_commit_push_fused_op(
            lp, got_v, cap_server=lo.cap_server, cap_pull=cap_pull,
            interpret=interpret)
    else:
        buf = _coo_reduce(
            jnp.zeros((lo.cap_server, *dense.shape[1:]), dense.dtype),
            lp, got_v, backend=backend, interpret=interpret)
        srv_mask = _mask(buf)
        lpos, ov_p = compact_indices(srv_mask, cap_pull)
        vals = _gather_rows(buf, lpos)

    # --- 4b. Pull -------------------------------------------------------------
    if use_hash_bitmap:
        if not fuse:
            bm = formats.bitmap_encode(srv_mask, backend=backend,
                                       interpret=interpret)
        all_bm = lax.all_gather(bm, axis)                 # [n, W]
        all_val = lax.all_gather(vals, axis)              # [n, cap_pull(,d)]
        # fused decode: one batched unpack + compaction + permutation gather
        # (replaces the per-server vmapped closure)
        if fuse:
            lpos_all = formats.bitmap_decode_compact(
                all_bm, lo.cap_server, cap_pull, backend="pallas",
                interpret=interpret)
        else:
            m_all = formats.bitmap_decode_batch(
                all_bm, lo.cap_server, backend=backend, interpret=interpret)
            lpos_all, _ = compact_rows(m_all, cap_pull)   # [n, cap_pull]
        gidx = jnp.clip(tabs.offsets[:n, None] + lpos_all, 0, lo.length - 1)
        glob = jnp.where(lpos_all == EMPTY, EMPTY, tabs.perm[gidx])
        pull_words = (n - 1) * (_nnz(lpos) * vw + lo.cap_bitmap_words)
    else:  # COO pull (ablation)
        glob_l = jnp.where(
            lpos == EMPTY, EMPTY,
            tabs.perm[jnp.clip(tabs.offsets[lax.axis_index(axis)] + lpos,
                               0, lo.length - 1)])
        glob = lax.all_gather(glob_l, axis)
        all_val = lax.all_gather(vals, axis)
        pull_words = (n - 1) * _nnz(lpos) * (vw + 1)

    # final decode-apply stays in XLA on both backends: its output is the
    # full-length gradient, too large for the VMEM-resident scatter kernel
    # (which is sized for the compact server buffer).  Thm. 2 makes the
    # decoded targets globally unique, so it needs no combiner.
    out = _scatter_unique(jnp.zeros_like(dense), glob.reshape(-1),
                          all_val.reshape(-1, *dense.shape[1:]))

    my_rank = lax.axis_index(axis)
    push_sent = (jnp.sum(jax.vmap(_nnz)(pidx)) - _nnz(pidx[my_rank])) * (1 + vw)
    stats = SyncStats(
        sent_words=push_sent + pull_words,
        overflow=enc.overflow + ov_p,
    )
    return out, stats


def zen_sync(
    dense: jnp.ndarray, *, axis: str, layout: ZenLayout,
    use_hash_bitmap: bool = True, backend: str = "xla",
    interpret: bool | None = None, fused: bool | None = None,
    fused_commit: bool | None = None,
) -> tuple[jnp.ndarray, SyncStats]:
    """Zen synchronization: Alg. 1 push + Alg. 2 (hash bitmap) pull.

    1. Compact local non-zero indices; hierarchically hash into n balanced
       partitions (h0 fixes the server; h1..hk + serial memory place them).
    2. Push: all_to_all of (indices, values) — balanced by Thm. 2.
    3. Aggregate: each server scatter-adds into its compact partition buffer
       (positions = offline local_pos, so same index from all workers lands
       in the same slot — complete aggregation).
    4. Pull: all_gather of (hash bitmap, non-zero values) — constant-size
       index metadata by Thm. 3.  With ``use_hash_bitmap=False``, pull uses
       COO (the Fig. 18 ablation).

    ``backend`` selects the compute route for the encode/decode stages:
    "xla" is pure jnp; "pallas" fuses the hash stage, bitmap pack/unpack,
    row compaction, and scatter-add through ``repro.kernels.ops`` (interpret
    mode off-TPU, real kernels on TPU).  Both routes are sort-free and
    value-identical.

    Implemented as ``zen_encode`` (stage 1, collective-free) followed by
    ``zen_commit`` (stages 2-4) — the split the bucketed overlap schedule
    pipelines (DESIGN.md §7).
    """
    enc = zen_encode(dense, layout=layout, backend=backend,
                     interpret=interpret, fused=fused)
    return zen_commit(enc, dense, axis=axis, layout=layout,
                      use_hash_bitmap=use_hash_bitmap, backend=backend,
                      interpret=interpret, fused=fused_commit)


# ---------------------------------------------------------------------------
# CommPlan execution: per-stage dispatch + the hierarchical composer
# ---------------------------------------------------------------------------

def stage_sync(
    scheme: str, dense: jnp.ndarray, *, axis: str, n: int,
    stage_args: StageArgs | None = None, **kw,
) -> tuple[jnp.ndarray, SyncStats]:
    """Run one scheme over one named axis — the uniform entry the
    CommPlan interpreter (``hier_sync``) and the bucket committer
    (``core/zen.py``) dispatch through.

    Dispatch is registry-driven (``repro.core.registry``): the scheme's
    :class:`SchemeSpec` names the executable function, the
    :class:`StageArgs` fields it consumes, and which are mandatory.
    Callers pass either a typed ``stage_args`` or loose keyword
    arguments (collected into one); validation raises config-named
    ValueErrors *before* the trace, so a mis-provisioned plan fails at
    plan-build time, not inside jit.  Capacity knobs are the caller's:
    a stage after an intra merge must provision for the *merged* density
    (``costmodel.merged_profile``), not the per-worker one — see
    :func:`plan_stage_args` for the one place that computes them."""
    spec = sreg.get_scheme(scheme)
    if stage_args is None:
        try:
            stage_args = StageArgs(**kw)
        except TypeError:
            valid = ", ".join(f.name for f in dataclasses.fields(StageArgs))
            bad = ", ".join(sorted(set(kw) - {
                f.name for f in dataclasses.fields(StageArgs)}))
            raise ValueError(
                f"stage_sync({scheme!r}): unknown stage arg(s) {bad}; "
                f"StageArgs fields are: {valid}") from None
    elif kw:
        raise ValueError(
            "stage_sync: pass a typed stage_args OR loose keyword "
            f"arguments, not both (got stage_args and {sorted(kw)})")
    sreg.validate_stage_args(spec, stage_args,
                             where=f"stage over axis {axis!r}")
    kwargs = sreg.stage_kwargs(spec, stage_args)
    if spec.needs_n:
        kwargs["n"] = n
    return spec.resolve_sync()(dense, axis=axis, **kwargs)


def level_budget(topology, budget: float, level: int) -> float:
    """Capacity budget for a plan stage at ``level``: stages after the
    intra merge provision for the worst-case merged density (the
    product of earlier level sizes' non-overlapping nonzeros in one
    tensor) — the capacity-growth boundary semantics of DESIGN.md §10.
    Level 0 passes the configured budget through untouched (the flat
    path must stay byte-identical to the pre-topology stack)."""
    if level == 0:
        return budget
    grow = math.prod(lv.size for lv in topology.levels[:level])
    return min(1.0, budget * grow)


def stage_args_for(
    scheme: str, *, rows: int, budget: float,
    layout: ZenLayout | None = None, use_hash_bitmap: bool = True,
    backend: str = "xla", interpret: bool | None = None,
    fused: bool | None = None, fused_commit: bool | None = None,
) -> StageArgs:
    """Provision one stage's :class:`StageArgs` from a density budget —
    the single place capacity sizing lives (GradSync, ``simulate_hier``
    harnesses, and benchmarks all route through here instead of
    hand-picking per-scheme kwargs).  ``cap = max(64, rows * budget)``
    with the omnireduce block split preserved bit-for-bit from the
    pre-registry provisioning."""
    cap = max(64, int(rows * budget))
    if scheme == "dense":
        return StageArgs()
    if scheme == "zen":
        return StageArgs(layout=layout, use_hash_bitmap=use_hash_bitmap,
                         backend=backend, interpret=interpret, fused=fused,
                         fused_commit=fused_commit)
    if scheme == "omnireduce":
        blk = 8
        nb = max(8, cap // blk)
        return StageArgs(block=blk, cap_push=nb, cap_pull=nb)
    # COO-capacity family: agsparse, sparcml, sparse_ps, balanced — the
    # registry's arg aliases fan ``capacity`` into cap_push/cap_pull.
    return StageArgs(capacity=cap)


def plan_stage_args(
    plan, topology, rows: int, *, density_budget: float, key: int = 0,
    k: int = 3, r1_factor: float = 2.0, r2_ratio: float = 0.1,
    backend: str = "xla", use_hash_bitmap: bool = True,
    fused: bool | None = None, fused_commit: bool | None = None,
    interpret: bool | None = None,
) -> dict[int, StageArgs]:
    """Provision every stage of a CommPlan: {level -> StageArgs}, with
    size-1 levels skipped (free identity — ``hier_sync`` never
    dispatches them) and capacity grown across the intra-merge boundary
    via :func:`level_budget`.  Zen stages get a fresh layout sized for
    the level's *merged* budget.  Each stage is validated against the
    registry so a bad plan fails here, with the level named, not inside
    the jit trace."""
    out: dict[int, StageArgs] = {}
    for stage in plan.stages:
        lvl = topology.levels[stage.level]
        if lvl.size <= 1:
            continue
        b = level_budget(topology, density_budget, stage.level)
        layout = None
        if stage.scheme == "zen":
            layout = make_zen_layout(rows, lvl.size, density_budget=b,
                                     key=key, k=k, r1_factor=r1_factor,
                                     r2_ratio=r2_ratio)
        args = stage_args_for(
            stage.scheme, rows=rows, budget=b, layout=layout,
            use_hash_bitmap=use_hash_bitmap, backend=backend,
            interpret=interpret, fused=fused, fused_commit=fused_commit)
        sreg.validate_stage_args(
            sreg.get_scheme(stage.scheme), args,
            where=f"plan stage {stage.scheme}@level{stage.level}")
        out[stage.level] = args
    return out


def hier_sync(
    dense: jnp.ndarray, *, topology, plan, stage_kw: dict | None = None,
) -> tuple[jnp.ndarray, SyncStats]:
    """Execute a CommPlan over a Topology: stage 0 aggregates over the
    fast (intra) axis, stage 1 runs on the *intra-aggregated* gradient
    over the slow (inter) axis.  Exact by associativity of the sum.

    ``stage_kw`` maps level index -> that stage's arguments, as either a
    typed :class:`StageArgs` (what :func:`plan_stage_args` builds) or a
    loose kwargs dict.  Size-1 levels are skipped (free identity) and
    report zero wire words.  Returns the
    SUM over all ``topology.n`` workers (same convention as every flat
    ``*_sync``) with ``SyncStats.by_level`` carrying the per-level wire
    split the inter-volume regression gate tracks."""
    stage_kw = stage_kw or {}
    g = dense
    sent = jnp.float32(0)
    overflow = jnp.int32(0)
    by_level = []
    for stage in plan.stages:
        lvl = topology.levels[stage.level]
        if lvl.size <= 1:
            by_level.append(jnp.float32(0))
            continue
        kw = stage_kw.get(stage.level, {})
        if isinstance(kw, StageArgs):
            g, st = stage_sync(stage.scheme, g, axis=lvl.axis,
                               n=lvl.size, stage_args=kw)
        else:
            g, st = stage_sync(stage.scheme, g, axis=lvl.axis,
                               n=lvl.size, **kw)
        sent = sent + st.sent_words
        overflow = overflow + st.overflow
        by_level.append(st.sent_words)
    return g, SyncStats(sent_words=sent, overflow=overflow,
                        by_level=tuple(by_level))


# ---------------------------------------------------------------------------
# Registry + single-device simulation helper
# ---------------------------------------------------------------------------

AXIS = "sync"


def simulate(fn, per_worker_dense: jnp.ndarray, **kwargs):
    """Run a scheme over [n, M(, d)] worker gradients on one device via vmap.

    Returns (aggregated [n, M(, d)] — identical rows, SyncStats batched)."""
    f = functools.partial(fn, axis=AXIS, **kwargs)
    return jax.vmap(f, axis_name=AXIS)(per_worker_dense)


def simulate_hier(per_worker_dense: jnp.ndarray, *, topology, plan,
                  stage_kw: dict | None = None, fn=None):
    """Single-device simulation of a hierarchical plan: [n, M(, d)] worker
    gradients nested-vmapped as [n_inter, n_intra, M(, d)] with one named
    axis per topology level (workers of a node are CONSECUTIVE rows —
    the same contiguous grouping ``launch/mesh.py`` builds).

    ``fn`` overrides the per-worker function (default: ``hier_sync`` of
    ``plan``); it receives the local dense gradient only."""
    topo = topology
    if fn is None:
        fn = functools.partial(hier_sync, topology=topo, plan=plan,
                               stage_kw=stage_kw)
    n_intra, n_inter = topo.intra.size, topo.inter.size
    per = per_worker_dense.reshape(
        n_inter, n_intra, *per_worker_dense.shape[1:])
    g = jax.vmap(jax.vmap(fn, axis_name=topo.intra.axis),
                 axis_name=topo.inter.axis)(per)
    return jax.tree.map(
        lambda x: x.reshape(n_inter * n_intra, *x.shape[2:]), g)

"""Sparsity characteristics of gradient tensors (§2.2, Defs. 3–6).

All metrics operate on boolean non-zero masks (element- or row-granularity),
so they apply uniformly to the paper's element-sparse COO setting and our
row-sparse embedding-gradient setting.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def density(mask: jnp.ndarray) -> jnp.ndarray:
    """d_G: fraction of non-zero gradients (Def. in §2.1)."""
    return jnp.mean(mask.astype(jnp.float32))


def overlap_ratio(mask_a: jnp.ndarray, mask_b: jnp.ndarray) -> jnp.ndarray:
    """Def. 3: |I1 ∩ I2| / min(|I1|, |I2|)."""
    inter = jnp.sum((mask_a & mask_b).astype(jnp.float32))
    lo = jnp.minimum(jnp.sum(mask_a.astype(jnp.float32)),
                     jnp.sum(mask_b.astype(jnp.float32)))
    return inter / jnp.maximum(lo, 1.0)


def aggregated_mask(masks: jnp.ndarray) -> jnp.ndarray:
    """Union of per-worker masks [n, M] -> [M] (non-zeros after aggregation;
    exact value-cancellation is measure-zero and ignored, as in the paper)."""
    return jnp.any(masks, axis=0)


def densification_ratio(masks: jnp.ndarray) -> jnp.ndarray:
    """Def. 4: γ_G^n = d_G^n / d_G, with d_G the mean per-worker density."""
    d_n = density(aggregated_mask(masks))
    d_1 = jnp.mean(jax.vmap(density)(masks))
    return d_n / jnp.maximum(d_1, 1e-12)


def skewness_ratio(mask: jnp.ndarray, n: int) -> jnp.ndarray:
    """Def. 5: s_G^n = max_i d_{G_i} / d_G over n equal contiguous partitions."""
    m = mask.shape[0]
    assert m % n == 0, "mask length must divide n for even partitioning"
    parts = mask.reshape(n, m // n).astype(jnp.float32)
    return jnp.max(jnp.mean(parts, axis=1)) / jnp.maximum(density(mask), 1e-12)


def imbalance_ratio_push(part_counts: jnp.ndarray) -> jnp.ndarray:
    """Def. 6 (Push): max_{i,j} n |I_i^j| / |I_i|.

    ``part_counts``: int [n_workers, n_servers] — worker i's non-zeros routed
    to server j.
    """
    n_srv = part_counts.shape[1]
    totals = jnp.sum(part_counts, axis=1, keepdims=True).astype(jnp.float32)
    frac = part_counts.astype(jnp.float32) / jnp.maximum(totals, 1.0)
    return n_srv * jnp.max(frac)


def imbalance_ratio_pull(server_counts: jnp.ndarray) -> jnp.ndarray:
    """Def. 6 (Pull): max_i n |𝕀_i| / |I| over aggregated per-server sets."""
    n = server_counts.shape[0]
    total = jnp.sum(server_counts).astype(jnp.float32)
    return n * jnp.max(server_counts.astype(jnp.float32)) / jnp.maximum(total, 1.0)


# ---------------------------------------------------------------------------
# Synthetic sparse-gradient generator calibrated to the paper's observations:
# skewed non-zero locations (C3), partial overlap across workers (C1),
# densification with worker count (C2).
# ---------------------------------------------------------------------------

def synth_sparse_masks(
    key: jax.Array,
    n_workers: int,
    length: int,
    density_target: float,
    *,
    skew: float = 1.5,
    shared_frac: float = 0.5,
) -> jnp.ndarray:
    """Draw [n_workers, length] masks reproducing the paper's characteristics.

    Non-zero positions follow a Zipf-like distribution over ``length``
    (embedding rows are token ids — frequency is Zipfian, which is exactly why
    the paper sees C3 skew: frequent tokens live at low indices in sorted
    vocabularies). ``shared_frac`` of each worker's draws come from a shared
    hot set (creating C1 partial overlap); the rest are worker-private.
    """
    nnz = max(1, int(length * density_target))
    seed = int(np.asarray(jax.random.randint(key, (), 0, 2**31 - 1)))
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, length + 1, dtype=np.float64)
    p = ranks ** (-skew)
    p /= p.sum()

    def draw_exact(r, k):
        """Draw until exactly k UNIQUE Zipf positions (preserves skew while
        hitting the target density exactly)."""
        got = np.unique(r.choice(length, size=4 * k, p=p))
        while len(got) < k:
            got = np.unique(np.concatenate(
                [got, r.choice(length, size=2 * k, p=p)]))
        r.shuffle(got)
        return got[:k]

    hot = draw_exact(rng, nnz)  # shared hot set
    masks = []
    for _ in range(n_workers):
        n_shared = int(nnz * shared_frac)
        own = draw_exact(rng, nnz)
        sh = rng.choice(hot, size=n_shared, replace=False)
        rest = own[~np.isin(own, sh)][: nnz - n_shared]
        m = np.zeros(length, bool)
        m[np.concatenate([sh, rest])] = True
        masks.append(m)
    return jnp.asarray(np.stack(masks))

"""Zen core: sparse-tensor synchronization (the paper's contribution).

Submodules:
  hashing    universal hash family + hierarchical hashing (Alg. 1)
  formats    COO / bitmap / tensor-block / hash-bitmap (Alg. 2) formats
  metrics    sparsity characteristics (Defs. 3–6)
  costmodel  analytical communication-time models (Fig. 7, Appendix B)
             + α-β times over topologies (DESIGN.md §10)
  topology   Topology + CommPlan IR — the shape of the DP world (§10)
  schemes    executable SPMD synchronization schemes (Table 2)
  zen        GradSync — gradient synchronization as a trainer feature
"""
from repro.core.hashing import (  # noqa: F401
    EMPTY,
    hierarchical_hash,
    extract_partitions,
    strawman_hash,
    make_seeds,
    compact_indices,
    compact_rows,
    partition_rank,
    row_compact,
)
from repro.core.schemes import (  # noqa: F401
    ZenLayout,
    make_zen_layout,
    zen_sync,
    dense_sync,
    agsparse_sync,
    sparcml_sync,
    sparse_ps_sync,
    omnireduce_sync,
    simulate,
)
from repro.core.schemes import (  # noqa: F401
    hier_sync,
    simulate_hier,
    stage_sync,
)
from repro.core.topology import (  # noqa: F401
    CommPlan,
    Topology,
    build_topology,
    flat_topology,
    hier_plan,
    parse_plan,
    two_level_topology,
)
from repro.core.zen import GradSync, SyncConfig  # noqa: F401

"""Sparse tensor formats (§3.2): COO, bitmap, tensor blocks, hash bitmap.

All formats are fixed-capacity / static-shape (see DESIGN.md §3).  Sizes in
*bytes on the wire* are reported by each format's ``wire_bytes`` so the
benchmark harness can reproduce Fig. 17 exactly.

Values may be scalars (element-sparse, the paper's setting) or rows of width
``d`` (row-sparse mode used for embedding-gradient synchronization, where a
"non-zero gradient" is an embedding row touched by the batch).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.hashing import EMPTY, compact_indices, compact_rows, hash_mod

BITS = 32  # paper assumes FP32 gradients; bitmap sizes are in FP32 words


# ---------------------------------------------------------------------------
# COO
# ---------------------------------------------------------------------------

class COO(NamedTuple):
    """Fixed-capacity coordinate list. ``indices`` EMPTY-padded."""

    indices: jnp.ndarray  # int32 [C]
    values: jnp.ndarray   # [C] or [C, d]
    overflow: jnp.ndarray  # int32 scalar — nnz beyond capacity (dropped)

    @property
    def capacity(self) -> int:
        return self.indices.shape[0]

    def nnz(self) -> jnp.ndarray:
        return jnp.sum((self.indices != EMPTY).astype(jnp.int32))

    def wire_bytes(self) -> jnp.ndarray:
        """4B index + 4B/value-element per non-zero (paper's 2x overhead)."""
        per = 1 if self.values.ndim == 1 else self.values.shape[-1]
        return self.nnz() * (4 + 4 * per)


@functools.partial(jax.jit, static_argnames=("capacity",))
def coo_encode(dense: jnp.ndarray, capacity: int) -> COO:
    """Dense [M] or [M, d] -> COO with ``capacity`` slots."""
    mask = dense != 0 if dense.ndim == 1 else jnp.any(dense != 0, axis=-1)
    idx, overflow = compact_indices(mask, capacity)
    safe = jnp.where(idx == EMPTY, 0, idx)
    vals = dense[safe]
    vals = jnp.where(
        (idx == EMPTY) if dense.ndim == 1 else (idx == EMPTY)[:, None], 0, vals
    )
    return COO(indices=idx, values=vals, overflow=overflow)


def coo_decode(coo: COO, length: int) -> jnp.ndarray:
    """COO -> dense [length(, d)] (scatter-add; duplicate indices aggregate,
    which is exactly the server-side aggregation semantics)."""
    shape = (length,) if coo.values.ndim == 1 else (length, coo.values.shape[-1])
    out = jnp.zeros(shape, dtype=coo.values.dtype)
    tgt = jnp.where(coo.indices == EMPTY, length, coo.indices)
    return out.at[tgt].add(coo.values, mode="drop")


# ---------------------------------------------------------------------------
# Plain bitmap (§3.2.1)
# ---------------------------------------------------------------------------

def bitmap_encode(
    mask: jnp.ndarray, *, backend: str = "xla",
    interpret: bool | None = None,
) -> jnp.ndarray:
    """bool [M] -> uint32 [ceil(M/32)] packed bitmap.

    ``backend="pallas"`` routes through the fused pack kernel in
    ``kernels/bitmap.py`` (bit-identical words: both pack LSB-first);
    ``interpret=None`` auto-resolves (real kernels on TPU only).
    """
    if backend == "pallas":
        from repro.kernels import ops  # deferred: kernels import this module

        return ops.bitmap_pack_op(mask, interpret=interpret)
    m = mask.shape[0]
    pad = (-m) % BITS
    bits = jnp.pad(mask.astype(jnp.uint32), (0, pad)).reshape(-1, BITS)
    weights = (jnp.uint32(1) << jnp.arange(BITS, dtype=jnp.uint32))
    return jnp.sum(bits * weights, axis=1, dtype=jnp.uint32)


def bitmap_decode(
    words: jnp.ndarray, length: int, *, backend: str = "xla",
    interpret: bool | None = None,
) -> jnp.ndarray:
    """uint32 [W] -> bool [length]."""
    if backend == "pallas":
        from repro.kernels import ops  # deferred: kernels import this module

        return ops.bitmap_unpack_op(words, length, interpret=interpret)
    weights = (jnp.uint32(1) << jnp.arange(BITS, dtype=jnp.uint32))
    bits = (words[:, None] & weights[None, :]) != 0
    return bits.reshape(-1)[:length]


def bitmap_decode_batch(
    words: jnp.ndarray, length: int, *, backend: str = "xla",
    interpret: bool | None = None,
) -> jnp.ndarray:
    """uint32 [n, W] -> bool [n, length]: all servers' bitmaps in one pass
    (the fused Pull decode of zen_sync — one batched unpack instead of a
    per-server closure)."""
    n, W = words.shape
    if backend == "pallas":
        from repro.kernels import ops  # deferred: kernels import this module

        bits = ops.bitmap_unpack_op(
            words.reshape(-1), n * W * BITS, interpret=interpret)
        return bits.reshape(n, W * BITS)[:, :length]
    weights = (jnp.uint32(1) << jnp.arange(BITS, dtype=jnp.uint32))
    bits = (words[:, :, None] & weights[None, None, :]) != 0
    return bits.reshape(n, -1)[:, :length]


def bitmap_decode_compact(
    words: jnp.ndarray, length: int, capacity: int, *,
    backend: str = "xla", interpret: bool | None = None,
) -> jnp.ndarray:
    """uint32 [n, W] -> int32 [n, capacity]: each server bitmap decoded
    straight to its compacted set-bit positions (ascending, EMPTY-padded)
    — the full zen pull decode in one call.

    ``backend="pallas"`` runs the fused pull megakernel
    (``kernels/zen_commit.py``: unpack + compact in one dispatch, one VMEM
    pass per server row); "xla" composes :func:`bitmap_decode_batch` +
    ``compact_rows`` — the two routes are bit-identical (CI kernel-parity
    matrix)."""
    if backend == "pallas":
        from repro.kernels import ops  # deferred: kernels import this module

        return ops.zen_commit_pull_fused_op(words, length, capacity,
                                            interpret=interpret)
    m = bitmap_decode_batch(words, length)
    return compact_rows(m, capacity)[0]


def bitmap_wire_bytes(length: int) -> int:
    return ((length + BITS - 1) // BITS) * 4


# ---------------------------------------------------------------------------
# Tensor blocks (OmniReduce's format)
# ---------------------------------------------------------------------------

class Blocks(NamedTuple):
    """Non-zero blocks of ``block`` consecutive gradients each."""

    block_ids: jnp.ndarray  # int32 [C] EMPTY-padded
    values: jnp.ndarray     # [C, block(, d)]
    overflow: jnp.ndarray

    def n_blocks(self) -> jnp.ndarray:
        return jnp.sum((self.block_ids != EMPTY).astype(jnp.int32))

    def wire_bytes(self) -> jnp.ndarray:
        per = self.values.shape[1] if self.values.ndim == 2 else (
            self.values.shape[1] * self.values.shape[2])
        return self.n_blocks() * (4 + 4 * per)


@functools.partial(jax.jit, static_argnames=("block", "capacity"))
def blocks_encode(dense: jnp.ndarray, block: int, capacity: int) -> Blocks:
    m = dense.shape[0]
    assert m % block == 0, "pad dense tensor to a block multiple"
    blocked = dense.reshape(m // block, block, *dense.shape[1:])
    mask = jnp.any(blocked != 0, axis=tuple(range(1, blocked.ndim)))
    ids, overflow = compact_indices(mask, capacity)
    safe = jnp.where(ids == EMPTY, 0, ids)
    vals = blocked[safe]
    dead = (ids == EMPTY).reshape((-1,) + (1,) * (vals.ndim - 1))
    vals = jnp.where(dead, 0, vals)
    return Blocks(block_ids=ids, values=vals, overflow=overflow)


def blocks_decode(blocks: Blocks, length: int) -> jnp.ndarray:
    block = blocks.values.shape[1]
    nb = length // block
    out = jnp.zeros((nb,) + blocks.values.shape[1:], dtype=blocks.values.dtype)
    tgt = jnp.where(blocks.block_ids == EMPTY, nb, blocks.block_ids)
    out = out.at[tgt].add(blocks.values, mode="drop")
    return out.reshape((length,) + blocks.values.shape[2:])


# ---------------------------------------------------------------------------
# Hash bitmap (§3.2.2, Alg. 2)
# ---------------------------------------------------------------------------

class HashBitmapLayout(NamedTuple):
    """Offline-computed layout shared by all workers and servers.

    ``perm``: int32 [M] — indices sorted by (h0(idx), idx); the concatenation
        of the per-server ordered sets I_0 .. I_{n-1} of §3.2.2.
    ``counts``: int32 [n] — |I_i| per server.
    ``offsets``: int32 [n+1] — prefix sum of counts.
    """

    perm: jnp.ndarray
    counts: jnp.ndarray
    offsets: jnp.ndarray

    @property
    def n(self) -> int:
        return self.counts.shape[0]


def make_hash_bitmap_layout(length: int, n: int, seeds: jnp.ndarray) -> HashBitmapLayout:
    """Precompute I_i = {idx : h0(idx) = i} (sorted), done once offline
    (§3.2.2: "I_i is computed and sorted offline and remains unchanged")."""
    idx = jnp.arange(length, dtype=jnp.int32)
    p = hash_mod(idx, seeds[0], n)
    order = jnp.argsort(p, stable=True)  # stable => ascending idx within I_i
    counts = jnp.bincount(p, length=n).astype(jnp.int32)
    offsets = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(counts)])
    return HashBitmapLayout(perm=order.astype(jnp.int32), counts=counts,
                            offsets=offsets.astype(jnp.int32))


def hash_bitmap_encode(dense: jnp.ndarray, layout: HashBitmapLayout) -> jnp.ndarray:
    """Alg. 2 encode, all servers at once: uint32 [ceil(M/32)].

    Server i's slice of the packed words covers positions
    [offsets[i], offsets[i+1]) of the permuted mask; total size is constantly
    M/32 words regardless of n (Thm. 3).
    """
    mask = dense != 0 if dense.ndim == 1 else jnp.any(dense != 0, axis=-1)
    return bitmap_encode(mask[layout.perm])


def hash_bitmap_decode(words: jnp.ndarray, layout: HashBitmapLayout) -> jnp.ndarray:
    """Alg. 2 decode: packed words -> bool [M] global non-zero mask."""
    permuted = bitmap_decode(words, layout.perm.shape[0])
    mask = jnp.zeros(layout.perm.shape[0], dtype=bool)
    return mask.at[layout.perm].set(permuted)


def hash_bitmap_wire_bytes(length: int) -> int:
    """Thm. 3: constant |G|/32 bits -> |G|/8 bytes... expressed in FP32 words:
    |G|/32 words = |G|/8 bytes total across all servers."""
    return ((length + BITS - 1) // BITS) * 4

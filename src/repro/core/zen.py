"""Gradient-synchronization API: Zen as a first-class trainer feature.

``GradSync`` maps a gradient pytree to its synchronized form inside a
``shard_map`` region.  Leaves named in ``sparse_rules`` (row-sparse tensors —
embedding tables in the assigned architectures) are synchronized with a
selectable sparse scheme over the data axis; everything else is a plain
``psum``.  A ``pod`` axis, when present, is reduced hierarchically after the
intra-pod sparse sync (paper §4.1 does the same with NVLink-intra /
network-inter).

Scheme selection is a config knob so the paper's baselines are runnable
end-to-end (Fig. 11/12 reproduction), not just as microbenchmarks.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import costmodel, schemes
from repro.core.schemes import SyncStats, ZenLayout, make_zen_layout


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    """How gradients are synchronized across the data-parallel axis."""

    scheme: str = "zen"           # zen | dense | agsparse | sparcml | sparse_ps | omnireduce | auto
    density_budget: float = 0.25  # capacity sizing for sparse buffers
    k: int = 3                    # Alg. 1 rehash rounds
    r1_factor: float = 2.0        # r1 = r1_factor * nnz_budget / n  (paper: 2)
    r2_ratio: float = 0.1         # r2 = r2_ratio * r1               (paper: 0.1)
    use_hash_bitmap: bool = True  # Alg. 2 on Pull (Fig. 18 ablation knob)
    seed: int = 0
    # 'auto' (beyond-paper): per-leaf offline choice — Zen wins iff the COO
    # push + bitmap pull volume under the density budget beats dense ring
    # allreduce; otherwise that leaf falls back to dense.  This prevents
    # Zen from LOSING on high-density tensors (paper Fig. 17's crossover).
    # The volume comparison lives in costmodel.zen_beats_dense, shared with
    # the Fig. 7 analytics.
    auto_threshold: float = 1.0   # zen_volume < threshold * dense_volume
    # Compute route for Zen's encode/decode stages: "xla" (pure jnp) or
    # "pallas" (fused kernels via repro.kernels.ops; interpret mode off-TPU).
    backend: str = "xla"


def _leaf_path_str(path) -> str:
    return "/".join(
        str(getattr(p, "key", getattr(p, "idx", p))) for p in path
    )


class GradSync:
    """Synchronize a gradient pytree across ``data`` (and ``pod``) axes.

    Args:
      cfg: SyncConfig.
      sparse_paths: list of path substrings marking row-sparse leaves
          (e.g. ``["embed/table"]``).  Matched leaves must be 2-D
          ``[rows, d]`` row-sparse tensors.
      grad_shapes: pytree of ShapeDtypeStruct matching the grads — used to
          precompute Zen layouts offline (per-leaf row counts).
      n_data: size of the data axis.
      data_axis / pod_axis: mesh axis names ('pod' may be None).
    """

    def __init__(
        self,
        cfg: SyncConfig,
        sparse_paths: list[str],
        grad_shapes: Any,
        n_data: int,
        data_axis: str = "data",
        pod_axis: str | None = None,
    ):
        self.cfg = cfg
        self.data_axis = data_axis
        self.pod_axis = pod_axis
        self.n_data = n_data
        self.sparse_paths = tuple(sparse_paths)
        self._layouts: dict[str, ZenLayout] = {}
        self._auto_dense: set[str] = set()
        leaves = jax.tree_util.tree_flatten_with_path(grad_shapes)[0]
        for path, leaf in leaves:
            name = _leaf_path_str(path)
            if not self._is_sparse(name):
                continue
            rows = leaf.shape[0] if len(leaf.shape) >= 1 else 1
            d = leaf.shape[1] if len(leaf.shape) > 1 else 1
            if cfg.scheme == "auto":
                # offline worst-case volume comparison — the same zen/dense
                # formulas as the Fig. 7 analytics (costmodel.SCHEMES)
                if not costmodel.zen_beats_dense(
                        rows, d, max(n_data, 2),
                        density_budget=cfg.density_budget,
                        threshold=cfg.auto_threshold):
                    self._auto_dense.add(name)
                    continue
            if cfg.scheme in ("zen", "auto"):
                self._layouts[name] = make_zen_layout(
                    rows, n_data,
                    density_budget=cfg.density_budget, key=cfg.seed,
                    k=cfg.k, r1_factor=cfg.r1_factor, r2_ratio=cfg.r2_ratio,
                )

    def _is_sparse(self, name: str) -> bool:
        return any(s in name for s in self.sparse_paths)

    # -- per-leaf sync -------------------------------------------------------

    def _sync_sparse(self, name: str, g: jnp.ndarray) -> tuple[jnp.ndarray, SyncStats]:
        cfg, ax, n = self.cfg, self.data_axis, self.n_data
        orig_shape = g.shape
        if g.ndim > 2:  # stacked-layer leaves: merge leading dims into rows?
            # embedding tables are [rows, d]; stacked variants unsupported
            raise ValueError(f"sparse leaf {name} must be 2-D, got {orig_shape}")
        cap = max(64, int(g.shape[0] * cfg.density_budget))
        if cfg.scheme == "auto" and name in self._auto_dense:
            out, st = schemes.dense_sync(g, axis=ax)
        elif cfg.scheme in ("zen", "auto"):
            out, st = schemes.zen_sync(
                g, axis=ax, layout=self._layouts[name],
                use_hash_bitmap=cfg.use_hash_bitmap, backend=cfg.backend)
        elif cfg.scheme == "agsparse":
            out, st = schemes.agsparse_sync(g, axis=ax, capacity=cap)
        elif cfg.scheme == "sparcml":
            out, st = schemes.sparcml_sync(g, axis=ax, n=n, capacity=cap)
        elif cfg.scheme == "sparse_ps":
            # imbalanced: needs skew headroom (cap is per-partition)
            out, st = schemes.sparse_ps_sync(
                g, axis=ax, n=n, cap_push=cap, cap_pull=cap)
        elif cfg.scheme == "omnireduce":
            blk = 8
            nb = max(8, cap // blk)
            out, st = schemes.omnireduce_sync(
                g, axis=ax, n=n, block=blk, cap_push=nb, cap_pull=nb)
        elif cfg.scheme == "dense":
            out, st = schemes.dense_sync(g, axis=ax)
        else:
            raise ValueError(f"unknown scheme {cfg.scheme}")
        return out / n, st  # mean-reduce convention (matches psum/n below)

    # -- pytree sync -----------------------------------------------------------

    def __call__(self, grads: Any) -> tuple[Any, dict[str, jnp.ndarray]]:
        """Synchronize grads (mean over data[, pod]); returns (grads, stats)."""
        sent = jnp.float32(0.0)
        overflow = jnp.int32(0)
        dense_words = jnp.float32(0.0)

        def sync_leaf(path, g):
            nonlocal sent, overflow, dense_words
            name = _leaf_path_str(path)
            if self._is_sparse(name):
                out, st = self._sync_sparse(name, g)
                sent = sent + st.sent_words
                overflow = overflow + st.overflow
            else:
                out = lax.psum(g, self.data_axis) / self.n_data
                dense_words = dense_words + jnp.float32(
                    2 * (self.n_data - 1) / self.n_data) * g.size
            if self.pod_axis is not None:
                out = lax.pmean(out, self.pod_axis)
            return out

        synced = jax.tree_util.tree_map_with_path(sync_leaf, grads)
        stats = {
            "sync/sparse_sent_words": sent,
            "sync/overflow": overflow,
            "sync/dense_words": dense_words,
        }
        return synced, stats

"""Gradient-synchronization API: Zen as a first-class trainer feature.

``GradSync`` maps a gradient pytree to its synchronized form inside a
``shard_map`` region.  Leaves named in ``sparse_rules`` (row-sparse tensors —
embedding tables in the assigned architectures) are synchronized with a
selectable sparse scheme over the data axis; everything else is a plain
``psum``.  A ``pod`` axis, when present, is reduced hierarchically after the
intra-pod sparse sync (paper §4.1 does the same with NVLink-intra /
network-inter).

Since the bucketed-scheduler refactor (DESIGN.md §7) the pytree is first
partitioned into fixed-byte buckets (``repro.core.buckets``): dense leaves
fuse into flat psum buckets, row-sparse leaves stay whole, and the per-bucket
sync ops are emitted double-buffered (``repro.train.schedule``) so XLA can
overlap bucket *i*'s collective with bucket *i+1*'s encode.
``bucket_bytes=None`` keeps the monolithic per-leaf path bit-exactly.

Scheme selection is a config knob so the paper's baselines are runnable
end-to-end (Fig. 11/12 reproduction), not just as microbenchmarks.  With
``scheme='auto'`` the choice is **per tensor**: each row-sparse leaf consults
its ``SparsityProfile`` (measured, via ``profiles``, or the worst-case budget
profile) through ``costmodel.choose_scheme``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import buckets as bk
from repro.core import costmodel, schemes
from repro.core.schemes import SyncStats, ZenLayout, make_zen_layout


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    """How gradients are synchronized across the data-parallel axis."""

    scheme: str = "zen"           # zen | dense | agsparse | sparcml | sparse_ps | omnireduce | auto
    density_budget: float = 0.25  # capacity sizing for sparse buffers
    k: int = 3                    # Alg. 1 rehash rounds
    r1_factor: float = 2.0        # r1 = r1_factor * nnz_budget / n  (paper: 2)
    r2_ratio: float = 0.1         # r2 = r2_ratio * r1               (paper: 0.1)
    use_hash_bitmap: bool = True  # Alg. 2 on Pull (Fig. 18 ablation knob)
    seed: int = 0
    # 'auto' (beyond-paper): per-leaf offline choice — Zen wins iff the COO
    # push + bitmap pull volume under the density budget beats dense ring
    # allreduce; otherwise that leaf falls back to dense.  This prevents
    # Zen from LOSING on high-density tensors (paper Fig. 17's crossover).
    # The volume comparison lives in costmodel.choose_scheme, shared with
    # the Fig. 7 analytics.
    auto_threshold: float = 1.0   # zen_volume < threshold * dense_volume
    # Compute route for Zen's encode/decode stages: "xla" (pure jnp) or
    # "pallas" (fused kernels via repro.kernels.ops; interpret mode off-TPU).
    backend: str = "xla"
    # Bucketed overlap scheduling (DESIGN.md §7): fuse dense grads into
    # buckets of at most this many bytes and emit per-bucket sync ops
    # double-buffered.  None = monolithic per-leaf path (bit-exact PR-1).
    bucket_bytes: int | None = None


class GradSync:
    """Synchronize a gradient pytree across ``data`` (and ``pod``) axes.

    Args:
      cfg: SyncConfig.
      sparse_paths: list of path substrings marking row-sparse leaves
          (e.g. ``["embed/table"]``).  Matched leaves must be 2-D
          ``[rows, d]`` row-sparse tensors.
      grad_shapes: pytree of ShapeDtypeStruct matching the grads — used to
          precompute Zen layouts and the bucket plan offline.
      n_data: size of the data axis.
      data_axis / pod_axis: mesh axis names ('pod' may be None).
      profiles: optional ``{leaf-path: SparsityProfile}`` of *measured*
          sparsity (e.g. from ``costmodel.profile_from_masks``).  Under
          scheme='auto' a profiled leaf is decided from its own curves
          instead of the worst-case density budget.
    """

    def __init__(
        self,
        cfg: SyncConfig,
        sparse_paths: list[str],
        grad_shapes: Any,
        n_data: int,
        data_axis: str = "data",
        pod_axis: str | None = None,
        profiles: dict[str, costmodel.SparsityProfile] | None = None,
    ):
        self.cfg = cfg
        self.data_axis = data_axis
        self.pod_axis = pod_axis
        self.n_data = n_data
        self.sparse_paths = tuple(sparse_paths)
        self._layouts: dict[str, ZenLayout] = {}
        profiles = profiles or {}

        def resolve_scheme(name: str, leaf) -> str:
            """Per-tensor scheme for one row-sparse leaf (bucket planner
            callback).  'auto' consults the leaf's own profile."""
            if len(leaf.shape) > 2:
                raise ValueError(
                    f"sparse leaf {name} must be 2-D, got {leaf.shape}")
            if cfg.scheme != "auto":
                return cfg.scheme
            rows = leaf.shape[0] if len(leaf.shape) >= 1 else 1
            d = leaf.shape[1] if len(leaf.shape) > 1 else 1
            prof = profiles.get(name)
            if prof is None:
                prof = costmodel.worst_case_profile(
                    rows, cfg.density_budget, vw=max(d, 1))
            return costmodel.choose_scheme(
                prof, max(n_data, 2), threshold=cfg.auto_threshold)

        self.plan = bk.make_bucket_plan(
            grad_shapes, self._is_sparse, cfg.bucket_bytes, resolve_scheme)
        for b in self.plan.buckets:
            if b.kind != bk.SPARSE or b.scheme != "zen":
                continue
            slot = b.slots[0]
            rows = slot.shape[0] if len(slot.shape) >= 1 else 1
            self._layouts[slot.name] = make_zen_layout(
                rows, n_data,
                density_budget=cfg.density_budget, key=cfg.seed,
                k=cfg.k, r1_factor=cfg.r1_factor, r2_ratio=cfg.r2_ratio,
            )

    def _is_sparse(self, name: str) -> bool:
        return any(s in name for s in self.sparse_paths)

    # -- per-bucket sync ------------------------------------------------------

    def _encode_bucket(self, bucket: bk.Bucket, payload: jnp.ndarray):
        """Local, collective-free stage (overlappable with the previous
        bucket's wire time).  Zen buckets encode to (indices, values);
        everything else passes through."""
        if bucket.scheme == "zen":
            enc = schemes.zen_encode(
                payload, layout=self._layouts[bucket.slots[0].name],
                backend=self.cfg.backend)
            return (payload, enc)
        return (payload,)

    def _commit_bucket(
        self, bucket: bk.Bucket, enc
    ) -> tuple[jnp.ndarray, SyncStats]:
        """Collective + decode-apply stage for one bucket."""
        cfg, ax, n = self.cfg, self.data_axis, self.n_data
        g = enc[0]
        if bucket.kind == bk.DENSE:
            out = lax.psum(g, ax) / n
            words = jnp.float32(2 * (n - 1) / n) * g.size
            st = SyncStats(sent_words=words, overflow=jnp.int32(0))
        else:
            name = bucket.slots[0].name
            cap = max(64, int(g.shape[0] * cfg.density_budget))
            if bucket.scheme == "zen":
                out, st = schemes.zen_commit(
                    enc[1], g, axis=ax, layout=self._layouts[name],
                    use_hash_bitmap=cfg.use_hash_bitmap,
                    backend=cfg.backend)
            elif bucket.scheme == "agsparse":
                out, st = schemes.agsparse_sync(g, axis=ax, capacity=cap)
            elif bucket.scheme == "sparcml":
                out, st = schemes.sparcml_sync(g, axis=ax, n=n, capacity=cap)
            elif bucket.scheme == "sparse_ps":
                # imbalanced: needs skew headroom (cap is per-partition)
                out, st = schemes.sparse_ps_sync(
                    g, axis=ax, n=n, cap_push=cap, cap_pull=cap)
            elif bucket.scheme == "omnireduce":
                blk = 8
                nb = max(8, cap // blk)
                out, st = schemes.omnireduce_sync(
                    g, axis=ax, n=n, block=blk, cap_push=nb, cap_pull=nb)
            elif bucket.scheme == "dense":
                out, st = schemes.dense_sync(g, axis=ax)
            else:
                raise ValueError(f"unknown scheme {bucket.scheme}")
            out = out / n  # mean-reduce convention (matches psum/n above)
        if self.pod_axis is not None:
            out = lax.pmean(out, self.pod_axis)
        return out, st

    # -- pytree sync ----------------------------------------------------------

    def __call__(self, grads: Any) -> tuple[Any, dict[str, jnp.ndarray]]:
        """Synchronize grads (mean over data[, pod]); returns (grads, stats)."""
        # deferred: core must not import the train layer at module scope
        from repro.train import schedule

        flat, treedef = jax.tree_util.tree_flatten(grads)
        payloads = [bk.gather_bucket(b, flat) for b in self.plan.buckets]
        outs, per_bucket = schedule.run_schedule(
            self.plan.buckets, payloads,
            self._encode_bucket, self._commit_bucket)
        synced_flat = list(flat)
        for b, out in zip(self.plan.buckets, outs):
            bk.scatter_bucket(b, out, synced_flat)
        synced = jax.tree_util.tree_unflatten(treedef, synced_flat)
        return synced, bk.reduce_stats(self.plan, per_bucket)

"""Gradient-synchronization API: Zen as a first-class trainer feature.

``GradSync`` maps a gradient pytree to its synchronized form inside a
``shard_map`` region.  Leaves named in ``sparse_rules`` (row-sparse tensors —
embedding tables in the assigned architectures) are synchronized with a
selectable sparse scheme over the data axis; everything else is a plain
``psum``.  A ``pod`` axis, when present, is reduced hierarchically after the
intra-pod sparse sync (paper §4.1 does the same with NVLink-intra /
network-inter).

Since the topology refactor (DESIGN.md §10) the data-parallel world itself
may be hierarchical: a two-level ``core/topology.py`` Topology (built from
``--node-size``) resolves every bucket to a **CommPlan** — e.g.
``hier(zen@dp_intra, agsparse@dp_inter)`` — whose stages run fastest level
first with capacities grown across the intra-merge boundary, and whose
stage 0 rides in its own fenced slot of the overlap schedule.  The flat
(degenerate) topology reproduces the single-axis stack bit-exactly.

Since the bucketed-scheduler refactor (DESIGN.md §7) the pytree is first
partitioned into fixed-byte buckets (``repro.core.buckets``): dense leaves
fuse into flat psum buckets, row-sparse leaves stay whole, and the per-bucket
sync ops are emitted double-buffered (``repro.train.schedule``) so XLA can
overlap bucket *i*'s collective with bucket *i+1*'s encode.
``bucket_bytes=None`` keeps the monolithic per-leaf path bit-exactly.

Scheme selection is a config knob so the paper's baselines are runnable
end-to-end (Fig. 11/12 reproduction), not just as microbenchmarks.  With
``scheme='auto'`` the choice is **per tensor**: each row-sparse leaf consults
its ``SparsityProfile`` (measured, via ``profiles``, or the worst-case budget
profile) through ``costmodel.choose_scheme``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import buckets as bk
from repro.core import costmodel, schemes, sparsify
from repro.core import topology as tpg
from repro.core.schemes import SyncStats, ZenLayout, make_zen_layout
from repro.core.topology import CommPlan, Topology, resolve_plan


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    """How gradients are synchronized across the data-parallel axis."""

    scheme: str = "zen"           # any registry scheme (see registry.cli_scheme_choices()) | auto
    density_budget: float = 0.25  # capacity sizing for sparse buffers
    k: int = 3                    # Alg. 1 rehash rounds
    r1_factor: float = 2.0        # r1 = r1_factor * nnz_budget / n  (paper: 2)
    r2_ratio: float = 0.1         # r2 = r2_ratio * r1               (paper: 0.1)
    use_hash_bitmap: bool = True  # Alg. 2 on Pull (Fig. 18 ablation knob)
    seed: int = 0
    # 'auto' (beyond-paper): per-leaf offline choice — Zen wins iff the COO
    # push + bitmap pull volume under the density budget beats dense ring
    # allreduce; otherwise that leaf falls back to dense.  This prevents
    # Zen from LOSING on high-density tensors (paper Fig. 17's crossover).
    # The volume comparison lives in costmodel.choose_scheme, shared with
    # the Fig. 7 analytics.
    auto_threshold: float = 1.0   # zen_volume < threshold * dense_volume
    # Compute route for Zen's encode/decode stages: "xla" (pure jnp) or
    # "pallas" (fused kernels via repro.kernels.ops; interpret mode off-TPU).
    backend: str = "xla"
    # Pallas backend only: route the encode path through the single-dispatch
    # megakernel (kernels/zen_encode.py, DESIGN.md §11) instead of the
    # 3-dispatch hash/extract/pack chain.  Both are bit-exact vs XLA.
    fused_encode: bool = True
    # Pallas backend only: route the commit path (server aggregation +
    # compaction + bitmap pack, and the batched pull decode) through the
    # commit megakernel pair (kernels/zen_commit.py, DESIGN.md §14).
    # Wire-exact vs the unfused chain (zenlint's fused-commit route).
    fused_commit: bool = True
    # Path to a CostCalibrator JSON table (DESIGN.md §11).  When set, the
    # 'auto' scheme decision adds *measured* per-stage encode overhead —
    # zen is only picked when its wire win survives what encode actually
    # costs on this machine.  Produce with `python -m repro.core.costmodel
    # --calib-file PATH` (or let launch/train.py --calib-file calibrate on
    # first use).  None = analytic α-β model (the historical decision).
    calib_file: str | None = None
    # Bucketed overlap scheduling (DESIGN.md §7): fuse dense grads into
    # buckets of at most this many bytes and emit per-bucket sync ops
    # double-buffered.  None = monolithic per-leaf path (bit-exact PR-1).
    bucket_bytes: int | None = None
    # α-β link-parameter override for the topology cost model
    # (DESIGN.md §10): 'a_intra,b_intra,a_inter,b_inter' in (µs, µs/word),
    # or 'a,b' for every level.  None = the core/topology.py defaults.
    # Only consulted when the trainer builds a hierarchical topology
    # (--node-size > 1); the flat cost model is volume-only (degenerate).
    alpha_beta: str | None = None
    # Error-feedback sparsification of dense buckets (DESIGN.md §8): a
    # core/sparsify.py spec string — 'topk:0.01', 'randk:0.05',
    # 'threshold:1e-3', optional ':noef' suffix — or 'none'.  Compressed
    # buckets are synchronized with a sparse scheme (under 'auto' the
    # cost model decides per bucket from the post-compression density);
    # the EF residual lives in optimizer state and must be threaded
    # through ``GradSync.__call__(grads, residual, step=...)``.
    compress: str = "none"


class GradSync:
    """Synchronize a gradient pytree across ``data`` (and ``pod``) axes.

    Args:
      cfg: SyncConfig.
      sparse_paths: list of path substrings marking row-sparse leaves
          (e.g. ``["embed/table"]``).  Matched leaves must be 2-D
          ``[rows, d]`` row-sparse tensors.
      grad_shapes: pytree of ShapeDtypeStruct matching the grads — used to
          precompute Zen layouts and the bucket plan offline.
      n_data: size of the data axis.
      data_axis / pod_axis: mesh axis names ('pod' may be None).
      profiles: optional ``{leaf-path: SparsityProfile}`` of *measured*
          sparsity (e.g. from ``costmodel.profile_from_masks``).  Under
          scheme='auto' a profiled leaf is decided from its own curves
          instead of the worst-case density budget.
    """

    def __init__(
        self,
        cfg: SyncConfig,
        sparse_paths: list[str],
        grad_shapes: Any,
        n_data: int,
        data_axis: str = "data",
        pod_axis: str | None = None,
        profiles: dict[str, costmodel.SparsityProfile] | None = None,
        topology: Topology | None = None,
    ):
        self.cfg = cfg
        self.data_axis = data_axis
        self.pod_axis = pod_axis
        self.n_data = n_data
        # The flat degenerate topology reproduces the pre-topology stack
        # bit-exactly (α=0, β=1: time == volume, one level over data_axis)
        self.topology = (topology if topology is not None
                         else tpg.flat_topology(n_data, axis=data_axis))
        if self.topology.n != n_data:
            raise ValueError(
                f"topology covers {self.topology.n} workers "
                f"({self.topology.describe()}) but n_data={n_data}")
        if self.topology.flat and self.topology.intra.axis != data_axis:
            raise ValueError(
                f"flat topology axis {self.topology.intra.axis!r} != "
                f"data_axis {data_axis!r}")
        self.sparse_paths = tuple(sparse_paths)
        self.compress = sparsify.parse_compress(cfg.compress)
        self._layouts: dict[tuple[str, int], ZenLayout] = {}
        profiles = profiles or {}
        topo = self.topology
        # measured-time calibration (DESIGN.md §11): loaded once at plan
        # time; every 'auto' decision below then prices encode overhead
        self.calib = (costmodel.CalibrationTable.load(cfg.calib_file)
                      if cfg.calib_file else None)

        def auto_target():
            """What 'auto' hands to choose_scheme: the historical int
            world size on flat topologies (bit-identical picks), the
            α-β topology when hierarchical (plan tags)."""
            return max(n_data, 2) if topo.flat else topo

        def resolve_scheme(name: str, leaf) -> str:
            """Per-tensor plan tag for one row-sparse leaf (bucket
            planner callback).  'auto' consults the leaf's own profile."""
            if len(leaf.shape) > 2:
                raise ValueError(
                    f"sparse leaf {name} must be 2-D, got {leaf.shape}")
            if cfg.scheme != "auto":
                return cfg.scheme
            rows = leaf.shape[0] if len(leaf.shape) >= 1 else 1
            d = leaf.shape[1] if len(leaf.shape) > 1 else 1
            prof = profiles.get(name)
            if prof is None:
                prof = costmodel.worst_case_profile(
                    rows, cfg.density_budget, vw=max(d, 1))
            return costmodel.choose_scheme(
                prof, auto_target(), threshold=cfg.auto_threshold,
                calib=self.calib)

        def resolve_compressed(key: str, size: int) -> str:
            """Plan tag for one EF-compressed dense bucket: 'auto' runs
            the cost model on the measured profile when one is available
            (the DensityController feedback loop), else on the configured
            keep-density's worst case."""
            if cfg.scheme != "auto":
                return cfg.scheme
            prof = profiles.get(key)
            if prof is None:
                prof = sparsify.compress_profile(self.compress, size)
            return costmodel.choose_scheme(
                prof, auto_target(), threshold=cfg.auto_threshold,
                calib=self.calib)

        self.plan = bk.make_bucket_plan(
            grad_shapes, self._is_sparse, cfg.bucket_bytes, resolve_scheme,
            compress=self.compress.tag(),
            compressed_scheme=resolve_compressed)
        # per-bucket executable CommPlans + per-(bucket, level) layouts
        self._plans: dict[int, CommPlan] = {
            b.bid: resolve_plan(b.scheme, topo) for b in self.plan.buckets}
        for b in self.plan.buckets:
            cplan = self._plans[b.bid]
            if b.kind == bk.SPARSE:
                slot = b.slots[0]
                rows = slot.shape[0] if len(slot.shape) >= 1 else 1
                budget = cfg.density_budget
            elif b.compress != "none":
                # compressed dense bucket: flat element-sparse payload
                rows = b.size
                budget = self._compressed_budget()
            else:
                continue  # plain dense psum bucket: no sparse buffers
            for stage in cplan.stages:
                lvl = topo.levels[stage.level]
                if stage.scheme != "zen" or lvl.size <= 1:
                    continue
                self._layouts[b.key, stage.level] = make_zen_layout(
                    rows, lvl.size,
                    density_budget=self._level_budget(budget, stage.level),
                    key=cfg.seed,
                    k=cfg.k, r1_factor=cfg.r1_factor, r2_ratio=cfg.r2_ratio,
                )

    def _is_sparse(self, name: str) -> bool:
        return any(s in name for s in self.sparse_paths)

    def _level_budget(self, budget: float, level: int) -> float:
        """Capacity budget for a stage at ``level`` — delegates to
        ``schemes.level_budget`` (the one shared implementation of the
        DESIGN.md §10 capacity-growth boundary; the simulate_hier test
        harnesses and benchmarks use the same function)."""
        return schemes.level_budget(self.topology, budget, level)

    def _compressed_budget(self) -> float:
        """Capacity budget for compressed buckets: 4x the configured
        keep-density (EF bursts and threshold drift need headroom; the
        overflow counters surface genuine violations — DESIGN.md §2)."""
        return min(1.0, 4 * self.compress.density)

    # -- error-feedback residual state ---------------------------------------

    @property
    def has_compression(self) -> bool:
        return self.compress.enabled

    def compressed_buckets(self) -> dict[str, int]:
        """{bucket key: payload element count} for every compressed
        bucket — the shape contract for residual state and the
        DensityController."""
        return {b.key: b.size for b in self.plan.buckets
                if b.compress != "none"}

    def bucket_schemes(self) -> dict[str, str]:
        """{bucket key: resolved scheme} for compressed buckets (what the
        DensityController compares its recommendations against)."""
        return {b.key: b.scheme for b in self.plan.buckets
                if b.compress != "none"}

    def describe(self) -> list[str]:
        """One human-readable line per bucket: the resolved CommPlan
        (tag expanded over the topology), kind, size, and compressor —
        what ``launch/train.py --node-size``/``dryrun.py`` print so the
        plan a run executes is visible, not inferred."""
        lines = [f"topology: {self.topology.describe()}"]
        if self.calib is not None:
            lines.append(
                f"calibration: {len(self.calib.entries)} measured entries "
                f"({self.calib.meta.get('device', '?')}) — 'auto' prices "
                f"encode overhead")
        for b in self.plan.buckets:
            cplan = self._plans[b.bid]
            stages = " ; ".join(
                f"{s.scheme}@{self.topology.levels[s.level].axis}"
                f"[{self.topology.levels[s.level].size}]"
                for s in cplan.stages)
            comp = "" if b.compress == "none" else f" compress={b.compress}"
            lines.append(
                f"bucket {b.bid:3d} {b.kind:11s} {b.nbytes:>10d}B "
                f"plan=[{stages}]{comp}  {b.key}")
        return lines

    def init_residual(self) -> dict:
        """Zero EF residual memory (one f32 vector per compressed bucket;
        empty when EF is off — plain lossy compression keeps no state)."""
        if not (self.compress.enabled and self.compress.ef):
            return {}
        return {k: jnp.zeros((s,), jnp.float32)
                for k, s in self.compressed_buckets().items()}

    # -- per-bucket sync ------------------------------------------------------

    def _stage_args(self, bucket: bk.Bucket, scheme: str,
                    level: int) -> schemes.StageArgs:
        """Typed :class:`StageArgs` for one plan stage of one bucket:
        capacities grow with the merged density after earlier levels.
        Provisioning lives in ``schemes.stage_args_for`` — the single
        shared implementation the test harnesses and benchmarks also
        route through."""
        cfg = self.cfg
        capd = (self._compressed_budget() if bucket.compress != "none"
                else cfg.density_budget)
        rows = (bucket.slots[0].shape[0] if bucket.kind == bk.SPARSE
                else bucket.size)
        return schemes.stage_args_for(
            scheme, rows=rows, budget=self._level_budget(capd, level),
            layout=self._layouts.get((bucket.key, level)),
            use_hash_bitmap=cfg.use_hash_bitmap, backend=cfg.backend,
            fused=cfg.fused_encode, fused_commit=cfg.fused_commit)

    def _encode_bucket(self, bucket: bk.Bucket, payload: jnp.ndarray):
        """Local, collective-free stage (overlappable with the previous
        bucket's wire time).  Buckets whose FIRST plan stage is Zen
        encode to (indices, values); everything else passes through.
        For compressed buckets the payload arriving here is already
        EF-sparsified (the schedule's compress hook runs in the same
        pipeline slot)."""
        stage0 = self._plans[bucket.bid].stages[0]
        if (stage0.scheme == "zen"
                and self.topology.levels[0].size > 1):
            enc = schemes.zen_encode(
                payload, layout=self._layouts[bucket.key, 0],
                backend=self.cfg.backend, fused=self.cfg.fused_encode)
            return (payload, enc)
        return (payload,)

    def _run_stage(self, bucket: bk.Bucket, level: int, g, enc=None):
        """Execute one plan stage; ``enc`` carries the prefetched
        ZenEncoded for stage 0 (the overlap schedule's contract)."""
        cplan = self._plans[bucket.bid]
        stage = cplan.stages[level]
        lvl = self.topology.levels[level]
        if lvl.size <= 1:
            return g, SyncStats(sent_words=jnp.float32(0),
                                overflow=jnp.int32(0))
        if stage.scheme == "zen" and enc is not None:
            return schemes.zen_commit(
                enc, g, axis=lvl.axis,
                layout=self._layouts[bucket.key, level],
                use_hash_bitmap=self.cfg.use_hash_bitmap,
                backend=self.cfg.backend, fused=self.cfg.fused_commit)
        args = self._stage_args(bucket, stage.scheme, level)
        return schemes.stage_sync(stage.scheme, g, axis=lvl.axis,
                                  n=lvl.size, stage_args=args)

    def _intra_bucket(self, bucket: bk.Bucket, enc):
        """Hierarchical stage 0: aggregate over the fast (intra) axis.
        Only wired into the schedule on two-level topologies — the
        pipeline fences it against the next bucket's encode so the cheap
        hop hides under compute (train/schedule.py)."""
        g = enc[0]
        zen_enc = enc[1] if len(enc) > 1 else None
        g1, st = self._run_stage(bucket, 0, g, enc=zen_enc)
        return (g1, st)

    def _commit_bucket(
        self, bucket: bk.Bucket, enc
    ) -> tuple[jnp.ndarray, SyncStats]:
        """Collective + decode-apply stage for one bucket.  Dispatch is
        by the bucket's CommPlan: an uncompressed dense bucket is a fused
        psum (per level); a compressed dense bucket goes through the
        sparse schemes on its flat (element-sparse) payload exactly like
        a row-sparse leaf.  On flat topologies this is the whole sync; on
        two-level topologies ``_intra_bucket`` already ran stage 0 and
        ``enc`` is ``(intra-aggregated payload, stage-0 stats)``."""
        n = self.n_data
        if self.topology.flat:
            g = enc[0]
            zen_enc = enc[1] if len(enc) > 1 else None
            out, st = self._run_stage(bucket, 0, g, enc=zen_enc)
            out = out / n  # mean-reduce convention (all schemes SUM)
        else:
            g_mid, st0 = enc
            out, st1 = self._run_stage(bucket, 1, g_mid)
            st = SyncStats(
                sent_words=st0.sent_words + st1.sent_words,
                overflow=st0.overflow + st1.overflow,
                by_level=(st0.sent_words, st1.sent_words))
            out = out / n
        if self.pod_axis is not None:
            out = lax.pmean(out, self.pod_axis)
        return out, st

    def encode_only(self, grads: Any) -> list:
        """Every bucket's local encode stage in isolation — no collectives,
        no mesh needed.  The measurement probe for the encode/commit time
        split (CostCalibrator, benchmarks/run.py ``stages``; DESIGN.md
        §11): wall-clock of this minus the full ``__call__`` attributes
        the e2e time stage-by-stage.  Uncompressed payloads only (the
        compress hook needs residual state — use ``__call__`` for that)."""
        from repro.train import schedule

        flat, _ = jax.tree_util.tree_flatten(grads)
        payloads = [bk.gather_bucket(b, flat) for b in self.plan.buckets]
        return schedule.encode_all(
            self.plan.buckets, payloads, self._encode_bucket)

    # -- pytree sync ----------------------------------------------------------

    def _compress_hook(self, residual, step, new_res: dict, extra: dict):
        """Build the schedule's compress stage.  Sparsified payloads flow
        on; residual updates and measured local densities d(1) are
        recorded in the caller's ``new_res`` / ``extra`` side channels."""
        ccfg = self.compress
        step = jnp.int32(0) if step is None else step

        def hook(bucket: bk.Bucket, payload):
            if bucket.compress == "none":
                return payload
            key = None
            if ccfg.kind == "randk":
                key = jax.random.fold_in(jax.random.fold_in(
                    jax.random.PRNGKey(ccfg.seed), bucket.bid), step)
            r = residual[bucket.key] if ccfg.ef else None
            sent, r_new, d1 = sparsify.compress_bucket(
                ccfg, payload, r, key=key)
            if r_new is not None:
                new_res[bucket.key] = r_new
            extra[sparsify.DENSITY1_KEY.format(key=bucket.key)] = d1
            return sent

        return hook

    def __call__(self, grads: Any, residual: dict | None = None, *,
                 step: jnp.ndarray | None = None):
        """Synchronize grads (mean over data[, pod]).

        Without compression: ``gs(grads) -> (synced, stats)``.  With
        compression, the EF residual state must be threaded through:
        ``gs(grads, residual, step=t) -> (synced, new_residual, stats)``
        (``step`` feeds randk's deterministic mask stream; topk/threshold
        ignore it).  Passing ``residual`` always selects the 3-tuple form
        so callers keep one code path per configuration.
        """
        # deferred: core must not import the train layer at module scope
        from repro.train import schedule

        if self.compress.enabled and self.compress.ef and residual is None:
            raise ValueError(
                "EF compression keeps residual state: call "
                "gs(grads, residual) with gs.init_residual() (or the "
                "optimizer-state copy) — a fresh zero residual every step "
                "would silently disable error feedback")
        new_res: dict = {}
        extra: dict = {}
        compress_fn = (self._compress_hook(residual, step, new_res, extra)
                       if self.compress.enabled else None)
        flat, treedef = jax.tree_util.tree_flatten(grads)
        payloads = [bk.gather_bucket(b, flat) for b in self.plan.buckets]
        outs, per_bucket = schedule.run_schedule(
            self.plan.buckets, payloads,
            self._encode_bucket, self._commit_bucket, compress=compress_fn,
            intra=None if self.topology.flat else self._intra_bucket)
        synced_flat = list(flat)
        for b, out in zip(self.plan.buckets, outs):
            if b.compress != "none":
                # measured post-aggregation density d(n): the second point
                # of the DensityController's feedback profile
                extra[sparsify.DENSITYN_KEY.format(key=b.key)] = jnp.mean(
                    (out != 0).astype(jnp.float32))
            bk.scatter_bucket(b, out, synced_flat)
        synced = jax.tree_util.tree_unflatten(treedef, synced_flat)
        stats = bk.reduce_stats(self.plan, per_bucket, extra)
        if residual is None:
            return synced, stats
        return synced, new_res, stats

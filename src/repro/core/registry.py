"""Scheme registry: the single surface a communication scheme plugs into.

Before this module, adding a scheme meant editing five hand-maintained
surfaces in lockstep: ``stage_sync``'s if/elif chain, the parallel
``costmodel.SCHEMES`` / ``costmodel.ROUNDS`` dicts, the ``choose_plan``
candidate tuple, and the hardcoded CLI ``choices=`` list in
``launch/train.py``.  A :class:`SchemeSpec` registered once via
:func:`register_scheme` now feeds all of them:

* ``schemes.stage_sync`` dispatches through :func:`get_scheme` (the
  executable ``sync_fn``, with per-scheme :class:`StageArgs` validation);
* ``costmodel.SCHEMES`` / ``costmodel.ROUNDS`` are live views over the
  registered ``volume_fn`` / ``rounds_fn``;
* ``costmodel.candidate_plans`` (flat and hierarchical) filters on
  ``plan_candidate`` + per-level feasibility;
* ``topology.parse_plan`` rejects unregistered scheme names, listing the
  registered ones;
* ``launch/train.py`` / ``launch/dryrun.py`` derive ``--sync`` choices
  from :func:`cli_scheme_choices`.

Import contract: this module is pure python (no jax, no numpy) and the
registrations live at the bottom of ``core/costmodel.py`` (which owns the
volume/round formulas and is itself importable on analysis-only rigs).
Executable sync functions are referenced *by name* and resolved lazily
from ``repro.core.schemes`` at dispatch time, so registering a scheme
never forces a jax import.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

# Histogram resolution of the balanced scheme's boundary rebalance: the
# index space is split into min(M, BALANCED_BINS) equal-width bins whose
# global multiset counts (one f32 allreduce) place the range boundaries.
# Shared between the executable scheme (core/schemes.py) and its α-β
# volume formula (core/costmodel.py) so claim and model cannot drift.
BALANCED_BINS = 256


@dataclasses.dataclass(frozen=True)
class StageArgs:
    """Typed per-stage arguments for one ``stage_sync`` call.

    One dataclass covers every scheme; a :class:`SchemeSpec` declares
    which fields it consumes (``stage_args``) and which are mandatory
    (``required_args``).  Setting a field a scheme does not consume is a
    config error surfaced at plan-build time (:func:`validate_stage_args`),
    in the style of ``make_ctx``'s ``validate_tp``.
    """

    capacity: int | None = None       # per-worker nnz budget (COO schemes)
    cap_push: int | None = None       # per-destination push slots (PS family)
    cap_pull: int | None = None       # aggregated-shard pull slots (PS family)
    block: int | None = None          # omnireduce block size
    bins: int | None = None           # balanced histogram bins (default: BALANCED_BINS)
    layout: Any = None                # ZenLayout (zen only)
    use_hash_bitmap: bool = True      # zen pull format (Fig. 18 ablation)
    backend: str = "xla"              # zen compute route: "xla" | "pallas"
    interpret: bool | None = None     # pallas interpret override (zen)
    fused: bool | None = None         # zen fused-encode megakernel toggle
    fused_commit: bool | None = None  # zen fused-commit megakernel toggle

    def set_fields(self) -> tuple[str, ...]:
        """Names of fields set to a non-default value."""
        return tuple(
            f.name for f in dataclasses.fields(self)
            if getattr(self, f.name) != f.default
        )


@dataclasses.dataclass(frozen=True)
class SchemeSpec:
    """Everything the repo needs to know about one communication scheme.

    ``sync_fn`` is the attribute name of the executable function on
    ``repro.core.schemes`` (resolved lazily — see module docstring), or
    ``None`` for analytic-only entries (``balanced_parallelism``,
    ``lower_bound``) that exist purely as cost-model curves.
    """

    name: str
    sync_fn: str | None                       # attr name on repro.core.schemes
    volume_fn: Callable                       # (SparsityProfile, n) -> words
    rounds_fn: Callable[[int], float]         # n -> message rounds (α term)
    stage_args: tuple[str, ...] = ()          # StageArgs fields consumed
    required_args: tuple = ()                 # names, or tuples = any-of groups
    arg_aliases: tuple = ()                   # ((src, (dst, ...)), ...): src fills unset dsts
    arg_defaults: tuple = ()                  # ((field, value), ...) when unset
    needs_n: bool = False                     # sync_fn takes a static n kwarg
    plan_candidate: bool = False              # choose_plan may pick it
    feasible_fn: Callable[[int, int], bool] | None = None  # (n, M) -> bool

    # -- zenlint metadata (repro.analysis; DESIGN.md §13) -----------------
    # wire_words_fn(M, n, kw) -> exact per-device wire words the lowered
    # program must emit at the given stage kwargs (value width 1); kw is
    # the stage_kwargs() output.  None on an executable scheme is itself
    # a lint finding: a scheme cannot land without its wire contract.
    wire_words_fn: Callable | None = None
    # HLO base collective kinds the lowering may contain ("all-reduce",
    # "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
    expected_collectives: tuple[str, ...] = ()
    # saturable: a fully-dense payload at lint_caps_fn caps makes the
    # SyncStats claim equal the wire exactly (R2 ==); zen's hash buffers
    # are r1_factor over-provisioned by design, so it is not (claim <=)
    lint_saturable: bool = False
    lint_density: float = 1.0                 # payload density for the sweep
    # lint_caps_fn(M, n) -> StageArgs kwargs that exactly saturate the
    # scheme at that payload (schemes taking a layout build it in-driver)
    lint_caps_fn: Callable | None = None
    lint_exempt: tuple[str, ...] = ()         # waived rule ids, e.g. ("R5",)
    # extra compute-route variants the lint sweep must also certify:
    # ((label, ((StageArgs field, value), ...)), ...).  Each route re-runs
    # the flat R1-R5 sweep with those fields overridden — e.g. zen's
    # fused-commit megakernel route, which must not change a wire word.
    lint_routes: tuple = ()

    @property
    def executable(self) -> bool:
        return self.sync_fn is not None

    def resolve_sync(self) -> Callable:
        if self.sync_fn is None:
            raise ValueError(
                f"scheme {self.name!r} is analytic-only (a cost-model "
                f"curve, not an executable collective); executable "
                f"schemes: {', '.join(registered_schemes(executable_only=True))}")
        from repro.core import schemes  # deferred: keep the registry jax-free

        return getattr(schemes, self.sync_fn)

    def feasible(self, n: int, M: int = 0) -> bool:
        """Whether this scheme can run at a level of size ``n`` (static
        shape / divisibility constraints)."""
        if n <= 1:
            return self.name == "dense"  # size-1 level: only the free identity
        if self.feasible_fn is None:
            return True
        return self.feasible_fn(n, M)


_REGISTRY: dict[str, SchemeSpec] = {}


def register_scheme(
    name: str,
    sync_fn: str | None,
    volume_fn: Callable,
    rounds_fn: Callable[[int], float],
    stage_args: tuple[str, ...] = (),
    *,
    required_args: tuple = (),
    arg_aliases: tuple = (),
    arg_defaults: tuple = (),
    needs_n: bool = False,
    plan_candidate: bool = False,
    feasible_fn: Callable[[int, int], bool] | None = None,
    wire_words_fn: Callable | None = None,
    expected_collectives: tuple[str, ...] = (),
    lint_saturable: bool = False,
    lint_density: float = 1.0,
    lint_caps_fn: Callable | None = None,
    lint_exempt: tuple[str, ...] = (),
    lint_routes: tuple = (),
) -> SchemeSpec:
    """Register one scheme.  Re-registering a name replaces it (tests)."""
    valid = {f.name for f in dataclasses.fields(StageArgs)}
    unknown = [a for a in stage_args if a not in valid]
    if unknown:
        raise ValueError(
            f"register_scheme({name!r}): stage_args {unknown} are not "
            f"StageArgs fields ({', '.join(sorted(valid))})")
    spec = SchemeSpec(
        name=name, sync_fn=sync_fn, volume_fn=volume_fn,
        rounds_fn=rounds_fn, stage_args=tuple(stage_args),
        required_args=tuple(required_args), arg_aliases=tuple(arg_aliases),
        arg_defaults=tuple(arg_defaults), needs_n=needs_n,
        plan_candidate=plan_candidate, feasible_fn=feasible_fn,
        wire_words_fn=wire_words_fn,
        expected_collectives=tuple(expected_collectives),
        lint_saturable=lint_saturable, lint_density=lint_density,
        lint_caps_fn=lint_caps_fn, lint_exempt=tuple(lint_exempt),
        lint_routes=tuple(lint_routes))
    _REGISTRY[name] = spec
    return spec


def _ensure_registered() -> None:
    """Populate the registry on first use.  The registrations live at the
    bottom of ``core/costmodel.py`` (jax-free; owns the volume formulas)."""
    if not _REGISTRY:
        from repro.core import costmodel  # noqa: F401  (registration side effect)


def get_scheme(name: str) -> SchemeSpec:
    _ensure_registered()
    spec = _REGISTRY.get(name)
    if spec is None:
        raise ValueError(
            f"unknown scheme {name!r}: registered schemes are "
            f"{', '.join(registered_schemes())} "
            f"(add new ones via repro.core.registry.register_scheme)")
    return spec


def registered_schemes(*, executable_only: bool = False) -> tuple[str, ...]:
    _ensure_registered()
    return tuple(n for n, s in _REGISTRY.items()
                 if s.executable or not executable_only)


def plan_candidates() -> tuple[str, ...]:
    """Schemes ``choose_plan`` may pick, in registration order (dense
    first — argmin ties must resolve toward dense)."""
    _ensure_registered()
    return tuple(n for n, s in _REGISTRY.items() if s.plan_candidate)


def cli_scheme_choices() -> list[str]:
    """``--sync`` choices for launch/train.py and launch/dryrun.py: every
    executable scheme plus the per-tensor 'auto' decision."""
    return [*registered_schemes(executable_only=True), "auto"]


def validate_stage_args(spec: SchemeSpec, args: StageArgs, where: str = "") -> None:
    """Config-named errors for one stage's arguments, raised at
    plan-build time (not from inside a jit trace)."""
    ctx = f" ({where})" if where else ""
    accepted = set(spec.stage_args)
    stray = [f for f in args.set_fields() if f not in accepted]
    if stray:
        raise ValueError(
            f"scheme {spec.name!r} does not consume stage arg(s) "
            f"{', '.join(stray)}{ctx}; it accepts: "
            f"{', '.join(spec.stage_args) or '(none)'}")
    for req in spec.required_args:
        alts = req if isinstance(req, tuple) else (req,)
        if all(getattr(args, a) is None for a in alts):
            raise ValueError(
                f"scheme {spec.name!r} requires stage arg "
                f"{' or '.join(alts)}{ctx} — size it from the density "
                f"budget (see schemes.plan_stage_args / SyncConfig."
                f"density_budget)")


def stage_kwargs(spec: SchemeSpec, args: StageArgs) -> dict:
    """The keyword arguments ``spec``'s sync function actually receives:
    consumed fields only, aliases applied (e.g. ``capacity`` filling
    ``cap_push``/``cap_pull``), per-scheme defaults filled, unset (None)
    fields dropped so the function's own defaults apply."""
    vals = {f: getattr(args, f) for f in spec.stage_args}
    for src, dsts in spec.arg_aliases:
        for d in dsts:
            if vals.get(d) is None and vals.get(src) is not None:
                vals[d] = vals[src]
        vals.pop(src, None)
    for field, default in spec.arg_defaults:
        if vals.get(field) is None:
            vals[field] = default
    return {k: v for k, v in vals.items() if v is not None}


# ---------------------------------------------------------------------------
# Registry-coverage check (CI lint job + tests/test_registry_balanced.py)
# ---------------------------------------------------------------------------

def coverage_errors(tests_dir: str = "tests") -> list[str]:
    """Every registered scheme must carry a volume and a rounds function
    that evaluate sanely, and every *executable* scheme must appear in a
    tier-1 test file (the parity-test requirement).  Returns a list of
    violations (empty = covered)."""
    import glob
    import os

    _ensure_registered()
    from repro.core import costmodel as cm

    # probe profile with every curve populated (block curves included —
    # omnireduce's volume asserts on them)
    p = cm.SparsityProfile(
        M=1 << 12, d=lambda i: min(1.0, 0.1 * max(i, 1)),
        s=lambda n: 1.0,
        block_density=lambda i: min(1.0, 0.2 * max(i, 1)),
        block_max=lambda i, parts: min(1.0, 0.2 * max(i, 1)))
    corpus = ""
    for path in sorted(glob.glob(os.path.join(tests_dir, "test_*.py"))):
        with open(path) as f:
            corpus += f.read()
    errors = []
    for name in registered_schemes():
        spec = get_scheme(name)
        try:
            r = float(spec.rounds_fn(8))
            v = float(spec.volume_fn(p, 8))
        except Exception as e:  # pragma: no cover - defensive
            errors.append(f"{name}: volume/rounds evaluation failed: {e}")
            continue
        if not (r > 0):
            errors.append(f"{name}: rounds_fn(8) = {r} (must be > 0)")
        if not (v >= 0):
            errors.append(f"{name}: volume_fn(p, 8) = {v} (must be >= 0)")
        if spec.executable and f'"{name}"' not in corpus \
                and f"'{name}'" not in corpus \
                and (spec.sync_fn or "") not in corpus:
            errors.append(
                f"{name}: executable scheme has no tier-1 parity test "
                f"(no test under {tests_dir}/ mentions it)")
    return errors


def _main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="repro.core.registry",
        description="Registry-coverage check: every registered scheme has "
                    "volume, rounds, and (if executable) a tier-1 parity "
                    "test.  CI's lint job runs this (make check-registry).")
    ap.add_argument("--check-tests", default="tests",
                    help="directory of tier-1 tests to scan")
    args = ap.parse_args(argv)
    errors = coverage_errors(args.check_tests)
    names = registered_schemes()
    for e in errors:
        print(f"REGISTRY ERROR: {e}")
    print(f"registry coverage: {len(names)} schemes "
          f"({', '.join(names)}) — "
          f"{'FAIL' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    import sys

    sys.exit(_main())

"""Universal hashing and the hierarchical hashing algorithm (Zen, Alg. 1).

The paper implements Alg. 1 in CUDA with parallel thread writes and an
``atomicAdd`` serial-memory fallback.  TPUs expose no atomics at the program
level, so we adapt the mechanism (see DESIGN.md §3):

* parallel hash insertion becomes **round-synchronous scatter**: in round ``i``
  every still-pending index proposes slot ``h_i(idx)``; a ``scatter_min``
  resolves races deterministically (the GPU race resolved by hardware becomes a
  min-reduction — any winner is equally correct because only the *partition*
  assignment, fixed by ``h0``, must agree across workers);
* the paper's "write-and-read" collision check becomes a gather-and-compare
  after the scatter;
* the atomic counter for the serial region becomes a per-partition prefix sum
  (``atomicAdd`` over a counter *is* a prefix sum, serialized).

Everything is static-shape and jit-friendly: index sets are fixed-capacity
``int32`` vectors padded with ``EMPTY`` (int32 max).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

EMPTY = jnp.iinfo(jnp.int32).max  # sentinel for "no index in this slot"


# ---------------------------------------------------------------------------
# Universal hash family (MurmurHash3 finalizer, seeded — mirrors the paper's
# seeded MurmurHash; the fmix32 bijection with a seeded xor gives the bit
# mixing the Carter–Wegman guarantee of Thm. 2 relies on in practice).
# ---------------------------------------------------------------------------

def fmix32(h: jnp.ndarray) -> jnp.ndarray:
    """MurmurHash3 32-bit finalizer (a bijection on uint32)."""
    h = h.astype(jnp.uint32)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def hash_u32(x: jnp.ndarray, seed: int | jnp.ndarray) -> jnp.ndarray:
    """Seeded uint32 hash of int32/uint32 ``x``."""
    x = x.astype(jnp.uint32)
    seed = jnp.asarray(seed, dtype=jnp.uint32)
    # two mixing rounds with seed folded in twice (murmur-style)
    h = fmix32(x ^ seed)
    h = fmix32(h ^ (seed * jnp.uint32(0x9E3779B9)) ^ jnp.uint32(0x5BD1E995))
    return h


def hash_mod(x: jnp.ndarray, seed: int | jnp.ndarray, m: int) -> jnp.ndarray:
    """``h(x) mod m`` as int32 in ``[0, m)``."""
    return (hash_u32(x, seed) % jnp.uint32(m)).astype(jnp.int32)


def make_seeds(key: jax.Array | int, k: int) -> jnp.ndarray:
    """Generate ``k`` hash-function seeds.

    In the paper, Zen draws random seeds at startup and broadcasts them to all
    GPUs so every worker uses the same hash family (§3.1.3 "Hash consistency
    among workers").  In SPMD JAX the same effect falls out of passing the same
    ``seeds`` array into the jitted step on every device.
    """
    if isinstance(key, int):
        key = jax.random.PRNGKey(key)
    return jax.random.randint(
        key, (k,), minval=1, maxval=jnp.iinfo(jnp.int32).max, dtype=jnp.int32
    ).astype(jnp.uint32)


# ---------------------------------------------------------------------------
# Hierarchical hashing (Algorithm 1)
# ---------------------------------------------------------------------------

class HashPartition(NamedTuple):
    """Result of hierarchically hashing an index set into ``n`` partitions.

    ``memory`` is the ``n x (r1 + r2)`` index memory of Alg. 1 (EMPTY-padded).
    ``overflow`` counts indices that could not be placed because a partition's
    serial memory ``r2`` was exhausted (0 when capacities are sized per the
    paper's recipe r1 = 2|I|, r2 = r1/10; tests assert this).
    ``rounds_used`` is a per-round histogram of successful parallel writes
    (round k+1 = serial memory) for the Fig. 16 parameter study.
    """

    memory: jnp.ndarray      # int32 [n, r1 + r2]
    overflow: jnp.ndarray    # int32 scalar
    rounds_used: jnp.ndarray  # int32 [k + 1]


def partition_of(indices: jnp.ndarray, n: int, seeds: jnp.ndarray) -> jnp.ndarray:
    """First-level hash ``h0``: which of the ``n`` partitions an index goes to.

    This is the only hash that must be identical across workers — it fixes the
    server an index is pushed to, guaranteeing complete aggregation.
    """
    return hash_mod(indices, seeds[0], n)


def partition_rank(p: jnp.ndarray, surv: jnp.ndarray, n: int) -> jnp.ndarray:
    """Rank of each surviving entry among survivors of the same partition,
    in slot order — the ``atomicAdd`` counter of Alg. 1's serial region.

    Sort-free: a segmented cumulative sum over a [C, n] partition one-hot
    (O(C·n) fully-parallel integer adds; n is the mesh size, so small) instead
    of the previous stable ``argsort`` + ``searchsorted`` (O(C log C) and a
    ``sort`` op in the HLO).  Dead entries get rank -1.
    """
    onehot = (p[:, None] == jnp.arange(n, dtype=p.dtype)[None, :]) & surv[:, None]
    seg = jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1        # [C, n]
    safe_p = jnp.clip(p, 0, n - 1).astype(jnp.int32)
    rank = jnp.take_along_axis(seg, safe_p[:, None], axis=1)[:, 0]
    return jnp.where(surv, rank, -1)


@functools.partial(
    jax.jit,
    static_argnames=("n", "r1", "r2", "k", "backend", "interpret", "static_seeds"),
)
def hierarchical_hash(
    indices: jnp.ndarray,
    *,
    n: int,
    r1: int,
    r2: int,
    k: int,
    seeds: jnp.ndarray | None = None,
    backend: str = "xla",
    interpret: bool | None = None,
    static_seeds: tuple | None = None,
) -> HashPartition:
    """Algorithm 1, TPU-adapted (see module docstring).

    Args:
      indices: int32 [C] index set, EMPTY-padded (order irrelevant).
      n: number of partitions (= servers = mesh size of the sync axis).
      r1: parallel-memory slots per partition (paper recipe: ``2 |I| / n``
          per partition, i.e. twice the expected load).
      r2: serial-memory slots per partition (paper recipe: ``r1 / 10``).
      k: number of second-level hash functions (paper: 3).
      seeds: uint32 [k + 1]; ``seeds[0]`` is ``h0``, ``seeds[1:]`` are
          ``h1..hk``.
      backend: "xla" computes the hash rounds with jnp; "pallas" fuses all
          k+1 hash evaluations into one VMEM pass (kernels/hash_stage.py) and
          requires ``static_seeds``.
      interpret: run Pallas kernels in interpret mode; None (default) means
          auto — real kernels on TPU, interpret elsewhere.
      static_seeds: the same k+1 seeds as compile-time python ints — required
          by the pallas backend (seeds are drawn once per job, so baking them
          into the kernel matches the paper's broadcast-at-startup).

    Returns:
      HashPartition with the filled index memory.
    """
    if backend not in ("xla", "pallas"):
        raise ValueError(f"backend must be 'xla' or 'pallas', got {backend!r}")
    if seeds is None and static_seeds is not None:
        seeds = jnp.asarray(static_seeds, dtype=jnp.uint32)
    if seeds is None:
        raise ValueError("hierarchical_hash needs `seeds` (or `static_seeds`)")
    if seeds.shape[0] < k + 1:
        raise ValueError(f"need {k + 1} seeds, got {seeds.shape[0]}")
    row = r1 + r2
    valid = indices != EMPTY

    # --- hash stage: p = h0 mod n, q_i = h_i mod r1 for all k rounds --------
    if backend == "pallas":
        if static_seeds is None:
            raise ValueError(
                "backend='pallas' needs `static_seeds` (a tuple of k+1 python "
                "ints); pass tuple(int(s) for s in layout.seeds)")
        from repro.kernels import ops  # deferred: kernels import this module

        p, q = ops.hash_stage_op(
            indices, static_seeds[: k + 1], n=n, r1=r1, interpret=interpret)
        qs = [q[i] for i in range(k)]
    else:
        p = partition_of(indices, n, seeds)  # int32 [C]
        qs = [hash_mod(indices, seeds[i], r1) for i in range(1, k + 1)]

    memory = jnp.full((n * row,), EMPTY, dtype=jnp.int32)
    pending = valid
    rounds = []

    # --- k parallel rounds -------------------------------------------------
    for i in range(k):
        slot = jnp.clip(p, 0, n - 1) * row + jnp.clip(qs[i], 0, r1 - 1)
        # propose: only pending indices, only into currently-empty slots
        occupied = memory[slot] != EMPTY
        propose = pending & ~occupied
        cand = jnp.where(propose, indices, EMPTY)
        # scatter_min resolves same-round races deterministically; EMPTY is
        # int32 max so non-proposals never win a slot.
        memory = memory.at[slot].min(cand, mode="drop")
        # write-and-read check (paper §3.1.3 "No information loss")
        won = pending & (memory[slot] == indices) & propose
        rounds.append(jnp.sum(won.astype(jnp.int32)))
        pending = pending & ~won

    # --- serial memory: segmented-cumsum slot assignment (≙ atomicAdd) ------
    surv = pending
    rank = partition_rank(p, surv, n)
    fits = surv & (rank < r2)
    slot = jnp.clip(p, 0, n - 1) * row + r1 + jnp.clip(rank, 0, r2 - 1)
    memory = memory.at[jnp.where(fits, slot, n * row)].set(
        jnp.where(fits, indices, EMPTY), mode="drop"
    )
    rounds.append(jnp.sum(fits.astype(jnp.int32)))
    overflow = jnp.sum((surv & ~fits).astype(jnp.int32))

    return HashPartition(
        memory=memory.reshape(n, row),
        overflow=overflow,
        rounds_used=jnp.stack(rounds),
    )


def row_compact(mem: jnp.ndarray) -> jnp.ndarray:
    """Sort-free row compaction: live entries to the front of each row in
    slot order, EMPTY-padded tail.  Cumsum + scatter — no ``sort`` op."""
    valid = mem != EMPTY
    pos = jnp.cumsum(valid.astype(jnp.int32), axis=1) - 1
    rows = jnp.arange(mem.shape[0])[:, None]
    tgt = jnp.where(valid, pos, mem.shape[1])
    out = jnp.full_like(mem, EMPTY)
    return out.at[rows, tgt].set(jnp.where(valid, mem, EMPTY), mode="drop")


def extract_partitions(
    part: HashPartition, *, backend: str = "xla",
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Line 19–23 of Alg. 1: per-partition index extraction.

    Returns int32 [n, r1+r2] with each partition's live indices compacted to
    the front (EMPTY-padded, slot order preserved) — the ``nonzero()`` step,
    made static-shape by compaction instead of a dynamic-size result.  Cheap
    because the memory is already only ~2x the nnz (the paper's "negligible
    extraction overhead").  Sort-free on both backends: segmented cumsum
    compaction in jnp, or the Pallas kernel in ``kernels/compact.py``.
    """
    if backend == "pallas":
        from repro.kernels import ops  # deferred: kernels import this module

        return ops.row_compact_op(part.memory, interpret=interpret)
    return row_compact(part.memory)


# ---------------------------------------------------------------------------
# Strawman single-hash algorithm (Appendix A, Alg. 3) — lossy baseline
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n", "r"))
def strawman_hash(
    indices: jnp.ndarray, *, n: int, r: int, seed: int | jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Alg. 3: one universal hash into an ``n x r`` memory; collisions lose.

    Returns (memory [n, r], lost_count).  Used by the Fig. 8 / Fig. 14
    baselines to reproduce the information-loss-vs-memory dilemma.
    """
    valid = indices != EMPTY
    h = hash_u32(indices, seed) % jnp.uint32(n * r)
    slot = h.astype(jnp.int32)
    cand = jnp.where(valid, indices, EMPTY)
    memory = jnp.full((n * r,), EMPTY, dtype=jnp.int32)
    memory = memory.at[slot].min(cand, mode="drop")
    survived = valid & (memory[slot] == indices)
    lost = jnp.sum((valid & ~survived).astype(jnp.int32))
    return memory.reshape(n, r), lost


# ---------------------------------------------------------------------------
# Index-set utilities
# ---------------------------------------------------------------------------

def compact_indices(mask: jnp.ndarray, capacity: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Compact the positions where ``mask`` is True into an EMPTY-padded
    int32 [capacity] vector (ascending order).  Overflow beyond ``capacity``
    is counted and dropped.

    This is the static-shape equivalent of ``nonzero()``.
    """
    m = mask.astype(jnp.int32)
    pos = jnp.cumsum(m) - 1  # target slot for each True
    nnz = jnp.sum(m)
    src = jnp.arange(mask.shape[0], dtype=jnp.int32)
    tgt = jnp.where(mask & (pos < capacity), pos, capacity)
    out = jnp.full((capacity,), EMPTY, dtype=jnp.int32)
    out = out.at[tgt].set(jnp.where(mask, src, EMPTY), mode="drop")
    overflow = jnp.maximum(nnz - capacity, 0)
    return out, overflow


def compact_rows(mask: jnp.ndarray, capacity: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Batched ``compact_indices``: bool [n, M] -> (int32 [n, capacity]
    EMPTY-padded ascending positions per row, int32 [n] overflow).  One
    batched cumsum + scatter instead of a vmapped per-row closure."""
    m = mask.astype(jnp.int32)
    pos = jnp.cumsum(m, axis=1) - 1
    nnz = jnp.sum(m, axis=1)
    src = jnp.broadcast_to(
        jnp.arange(mask.shape[1], dtype=jnp.int32), mask.shape)
    tgt = jnp.where(mask & (pos < capacity), pos, capacity)
    rows = jnp.arange(mask.shape[0])[:, None]
    out = jnp.full((mask.shape[0], capacity), EMPTY, dtype=jnp.int32)
    out = out.at[rows, tgt].set(jnp.where(mask, src, EMPTY), mode="drop")
    overflow = jnp.maximum(nnz - capacity, 0)
    return out, overflow

"""Topology + CommPlan IR: the shape of the data-parallel world (DESIGN.md §10).

Until this module, every scheme in ``core/schemes.py`` took a single flat
``axis: str`` — an 8-device single-host ICI ring and 2 hosts x 4 devices
over DCN were indistinguishable.  The winning communication scheme flips
with topology (OkTopk's near-optimal sparse allreduce; S-SGD's DAG α-β
model), so the sync stack now plans against two small IR pieces:

* ``Topology`` — an ordered list of ``Level``s, **fastest first**: each
  level is a mesh/vmap axis name, its size, and the α-β parameters of the
  links at that level (``alpha`` = per-message-round latency in µs,
  ``beta`` = µs per FP32 word).  A flat world is a one-level topology; a
  ``--node-size k`` world is ``(dp_intra: k, dp_inter: n/k)``.  The
  **degenerate** flat topology uses ``alpha=0, beta=1`` so α-β *time*
  reduces exactly to word *volume* — the pre-topology cost model — and
  every scheme pick is bit-identical to the flat stack.

* ``CommPlan`` — what a bucket executes: an ordered list of ``Stage``s
  (scheme, level), run fastest-level first.  Aggregation over the
  data-parallel product axis is associative, so
  ``sum_all == sum_inter(sum_intra)`` and any per-level scheme
  composition is exact.  Grammar (round-trippable via ``parse_plan``):

      plan  := scheme                          -- flat, one stage
             | "hier(" scheme "@intra," scheme "@inter" ")"

  A flat plan's tag is just the scheme name, so ``Bucket.scheme`` tags
  from the flat era parse unchanged (plan-stable identity).

Pure-python and numpy-free: built offline, consumed by
``core/costmodel.py`` (α-β times), ``core/schemes.py`` (``hier_sync``),
``core/zen.py`` (per-level layouts), and ``launch/mesh.py`` (mesh axes).
"""
from __future__ import annotations

import dataclasses
import math

# Mesh/vmap axis names of a node-split data-parallel world.  ``dp_intra``
# indexes devices within a node (fast links), ``dp_inter`` indexes nodes
# (slow links).  The flat world keeps its historical single "data" axis.
DP_INTRA = "dp_intra"
DP_INTER = "dp_inter"

# Default α-β link parameters (µs, µs per FP32 word).  Within a node:
# ICI/NVLink-class, ~100 GB/s per link.  Across nodes: DCN-class,
# ~10 GB/s.  These are planning defaults, not measurements — override
# with ``--alpha-beta`` (launch/train.py) or ``parse_alpha_beta``.
ALPHA_INTRA = 1.0
BETA_INTRA = 4e-5      # 4 B / 1e11 B/s = 4e-5 µs/word
ALPHA_INTER = 10.0
BETA_INTER = 4e-4      # 4 B / 1e10 B/s


@dataclasses.dataclass(frozen=True)
class Level:
    """One rung of the topology: an axis of ``size`` peers whose links
    have latency ``alpha`` (µs/round) and inverse bandwidth ``beta``
    (µs per FP32 word)."""

    axis: str
    size: int
    alpha: float = 0.0
    beta: float = 1.0

    def __post_init__(self):
        if self.size < 1:
            raise ValueError(f"level {self.axis!r}: size must be >= 1, "
                             f"got {self.size}")
        if self.alpha < 0 or self.beta <= 0:
            raise ValueError(f"level {self.axis!r}: need alpha >= 0 and "
                             f"beta > 0, got α={self.alpha} β={self.beta}")


@dataclasses.dataclass(frozen=True)
class Topology:
    """Ordered levels, fastest (innermost) first."""

    levels: tuple[Level, ...]

    def __post_init__(self):
        if not self.levels:
            raise ValueError("topology needs at least one level")
        if len(self.levels) > 2:
            raise ValueError(
                f"only one- and two-level topologies are supported, got "
                f"{len(self.levels)} levels")
        names = [lv.axis for lv in self.levels]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate level axes: {names}")

    @property
    def n(self) -> int:
        """Total data-parallel world size (product of level sizes)."""
        return math.prod(lv.size for lv in self.levels)

    @property
    def flat(self) -> bool:
        return len(self.levels) == 1

    @property
    def intra(self) -> Level:
        return self.levels[0]

    @property
    def inter(self) -> Level:
        return self.levels[-1]

    @property
    def axes(self) -> tuple[str, ...]:
        """Level axis names fastest-first — note mesh construction orders
        them slowest-first (outer mesh dims vary slowest)."""
        return tuple(lv.axis for lv in self.levels)

    def describe(self) -> str:
        return " > ".join(
            f"{lv.axis}[{lv.size}] α={lv.alpha:g}µs β={lv.beta:g}µs/w"
            for lv in reversed(self.levels))


def flat_topology(n: int, axis: str = "data",
                  alpha: float = 0.0, beta: float = 1.0) -> Topology:
    """One-level topology.  The default (α=0, β=1) is the **degenerate**
    topology: α-β time == word volume, so cost-model behavior is exactly
    the historical flat stack."""
    return Topology((Level(axis=axis, size=n, alpha=alpha, beta=beta),))


def two_level_topology(
    n_intra: int, n_inter: int, *,
    intra_axis: str = DP_INTRA, inter_axis: str = DP_INTER,
    alpha_intra: float = ALPHA_INTRA, beta_intra: float = BETA_INTRA,
    alpha_inter: float = ALPHA_INTER, beta_inter: float = BETA_INTER,
) -> Topology:
    return Topology((
        Level(axis=intra_axis, size=n_intra,
              alpha=alpha_intra, beta=beta_intra),
        Level(axis=inter_axis, size=n_inter,
              alpha=alpha_inter, beta=beta_inter),
    ))


def parse_alpha_beta(spec: str | None) -> dict:
    """Parse an ``--alpha-beta`` override.

    ``"a_intra,b_intra,a_inter,b_inter"`` (µs, µs/word) for two-level
    topologies; ``"a,b"`` applies one pair to every level.  ``None`` / ""
    means the defaults.  Returns kwargs for ``two_level_topology``."""
    if not spec:
        return {}
    parts = [float(x) for x in str(spec).split(",")]
    if len(parts) == 2:
        a, b = parts
        return dict(alpha_intra=a, beta_intra=b,
                    alpha_inter=a, beta_inter=b)
    if len(parts) == 4:
        return dict(alpha_intra=parts[0], beta_intra=parts[1],
                    alpha_inter=parts[2], beta_inter=parts[3])
    raise ValueError(
        f"--alpha-beta wants 'alpha,beta' or "
        f"'a_intra,b_intra,a_inter,b_inter', got {spec!r}")


def build_topology(n: int, node_size: int = 1, *, axis: str = "data",
                   alpha_beta: str | None = None) -> Topology:
    """The launcher's topology constructor.

    ``node_size == 1`` returns the degenerate flat topology over the
    historical ``axis`` — every downstream decision is then bit-identical
    to the pre-topology stack.  ``node_size > 1`` splits the ``n``-way
    data-parallel world into ``n // node_size`` nodes of ``node_size``
    devices with the default (or overridden) α-β link parameters.
    ``node_size == n`` is a single node — still two-level, with a
    size-1 (free) inter level, so the code path is uniform."""
    if node_size <= 1:
        if alpha_beta:
            a, b = (parse_alpha_beta(alpha_beta)["alpha_intra"],
                    parse_alpha_beta(alpha_beta)["beta_intra"])
            return flat_topology(n, axis=axis, alpha=a, beta=b)
        return flat_topology(n, axis=axis)
    if n % node_size != 0:
        raise ValueError(
            f"node_size={node_size} does not divide the data-parallel "
            f"world n={n}; pick a divisor of {n}")
    return two_level_topology(node_size, n // node_size,
                              **parse_alpha_beta(alpha_beta))


# ---------------------------------------------------------------------------
# CommPlan
# ---------------------------------------------------------------------------

# role names used by the plan grammar, indexed by level position
_ROLES = ("intra", "inter")


@dataclasses.dataclass(frozen=True)
class Stage:
    """One plan step: run ``scheme`` over topology level ``level``."""

    scheme: str
    level: int


@dataclasses.dataclass(frozen=True)
class CommPlan:
    """An executable composition of per-level scheme stages, fastest
    level first.  ``hier_sync`` (core/schemes.py) interprets it."""

    stages: tuple[Stage, ...]

    def __post_init__(self):
        if not self.stages:
            raise ValueError("a CommPlan needs at least one stage")
        if [s.level for s in self.stages] != list(range(len(self.stages))):
            raise ValueError(
                f"stages must cover levels 0..k in order, got "
                f"{[s.level for s in self.stages]}")

    @property
    def flat(self) -> bool:
        return len(self.stages) == 1

    def tag(self) -> str:
        """Round-trippable plan tag.  Flat plans keep the bare scheme
        name — byte-identical to the pre-topology ``Bucket.scheme`` tags,
        so bucket identity survives the IR refactor."""
        if self.flat:
            return self.stages[0].scheme
        inner = ",".join(f"{s.scheme}@{_ROLES[s.level]}" for s in self.stages)
        return f"hier({inner})"

    def scheme_at(self, level: int) -> str:
        return self.stages[level].scheme


def flat_plan(scheme: str) -> CommPlan:
    return CommPlan((Stage(scheme, 0),))


def hier_plan(intra_scheme: str, inter_scheme: str) -> CommPlan:
    return CommPlan((Stage(intra_scheme, 0), Stage(inter_scheme, 1)))


def _check_scheme(scheme: str, tag: str) -> None:
    """Reject plan tags naming unregistered or analytic-only schemes at
    parse time (the registry lists the valid names in the error), so a
    typo'd ``--sync`` or bucket tag fails before any tracing."""
    from repro.core import registry as _registry  # deferred: no cycle at import

    spec = _registry.get_scheme(scheme)  # unknown -> ValueError w/ names
    if not spec.executable:
        raise ValueError(
            f"plan tag {tag!r}: scheme {scheme!r} is analytic-only (a "
            f"cost-model curve, not an executable collective); "
            f"executable schemes: "
            f"{', '.join(_registry.registered_schemes(executable_only=True))}")


def parse_plan(tag: str) -> CommPlan:
    """Inverse of ``CommPlan.tag()``.  Scheme tokens are validated
    against the scheme registry (``repro.core.registry``)."""
    tag = tag.strip()
    if not tag.startswith("hier("):
        if "@" in tag or "(" in tag:
            raise ValueError(f"malformed plan tag {tag!r}")
        _check_scheme(tag, tag)
        return flat_plan(tag)
    if not tag.endswith(")"):
        raise ValueError(f"malformed plan tag {tag!r}")
    stages = []
    parts = tag[len("hier("):-1].split(",")
    if len(parts) != len(_ROLES):
        raise ValueError(
            f"malformed plan tag {tag!r}: hier() wants exactly "
            f"{len(_ROLES)} '@role' stages ({', '.join(_ROLES)})")
    for i, part in enumerate(parts):
        scheme, _, role = part.strip().partition("@")
        if not scheme or role != _ROLES[i]:
            raise ValueError(
                f"malformed plan tag {tag!r}: stage {i} must be "
                f"'<scheme>@{_ROLES[i]}', got {part.strip()!r}")
        _check_scheme(scheme, tag)
        stages.append(Stage(scheme, i))
    return CommPlan(tuple(stages))


def resolve_plan(tag: str, topology: Topology) -> CommPlan:
    """A bucket's executable plan from its tag and the topology.

    A bare scheme tag on a hierarchical topology means "that scheme at
    every level" (the explicit ``--sync zen`` user intent, applied
    per-level); ``hier(...)`` tags carry their own per-level schemes and
    must match the topology's level count."""
    plan = parse_plan(tag)
    if plan.flat and not topology.flat:
        s = plan.stages[0].scheme
        return hier_plan(s, s)
    if len(plan.stages) != len(topology.levels):
        raise ValueError(
            f"plan {tag!r} has {len(plan.stages)} stages but the topology "
            f"has {len(topology.levels)} levels ({topology.describe()})")
    return plan

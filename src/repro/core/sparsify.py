"""Error-feedback gradient sparsification (DESIGN.md §8).

The paper's schemes assume the gradient arrives sparse (embedding / MoE
rows).  This module *induces* sparsity on dense gradients so the whole
scheme/cost-model stack (buckets, Zen, `costmodel.choose_scheme`) applies
to every workload, not only row-sparse tables:

* **Sparsifiers** — ``topk`` (largest-|g| elements, exactly
  ``ceil(density * M)`` kept), ``threshold`` (``|g| >= tau``), ``randk``
  (Bernoulli(density) mask, deterministic in ``(seed, step)``).  All are
  pure functions of their inputs: bit-exact under ``jit``, identical
  under ``vmap`` (the single-device worker simulation), and free of any
  host-side state.
* **Error feedback (EF / EF21 style)** — what compression drops is not
  lost: the residual ``r`` is carried in optimizer state
  (``opt_state['residual']``, one f32 vector per compressed bucket) and
  added back before the next compression: ``acc = g + r``,
  ``sent = S(acc)``, ``r' = acc - sent``.  This is the memory-
  compensation pattern that keeps top-k training convergent where plain
  top-k stalls (see tests/test_sparsify.py's quadratic counterexample).
  The residual is an ordinary pytree leaf: ZeRO-agnostic (it is already
  per-device local), checkpointable through ``checkpoint/io.py``.
* **Adaptive density control** — compression makes the *effective*
  density a measured, drifting quantity.  ``DensityController`` keeps an
  EMA of each compressed bucket's post-compression density curve (d(1)
  local, d(n) aggregated — the two points Zen's cost model needs) from
  the trainer's ``sync/ef_density*`` metrics, and re-runs
  ``costmodel.choose_scheme`` on the measured profile.  When the
  recommendation diverges from the live bucket plan the trainer replans
  (rebuild + recompile) — that is how ``scheme='auto'`` flips dense<->zen
  per bucket as density drifts during training.

Compression is applied per *bucket* (the fused flat payload of
``core/buckets.py``), inside the overlap window of the double-buffered
schedule (``train/schedule.py``): sparsify(i+1) runs while bucket i's
collective is on the wire.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import costmodel

KINDS = ("none", "topk", "threshold", "randk")


@dataclasses.dataclass(frozen=True)
class CompressConfig:
    """How dense gradient buckets are sparsified before synchronization."""

    kind: str = "none"        # none | topk | threshold | randk
    # topk/randk: fraction of elements kept.  For threshold it is the
    # *capacity budget* the sparse buffers are provisioned for (the
    # overflow counters surface violations — DESIGN.md §2 contract).
    density: float = 0.01
    threshold: float = 0.0    # threshold kind: keep |g| >= threshold
    ef: bool = True           # error-feedback residual memory
    seed: int = 0             # randk mask stream

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"compress kind must be one of {KINDS}, got {self.kind!r}")
        if self.kind in ("topk", "randk") and not 0 < self.density <= 1:
            raise ValueError(
                f"compress density must be in (0, 1], got {self.density}")

    @property
    def enabled(self) -> bool:
        return self.kind != "none"

    def tag(self) -> str:
        """Round-trippable spec string (the bucket plan's compress tag)."""
        if not self.enabled:
            return "none"
        arg = (self.threshold if self.kind == "threshold" else self.density)
        return f"{self.kind}:{arg:g}" + ("" if self.ef else ":noef")

    def keep_count(self, size: int) -> int:
        """Static per-bucket capacity in elements (k for top-k; the
        provisioning budget for threshold/randk)."""
        return max(1, min(size, int(math.ceil(size * self.density))))


def parse_compress(spec) -> CompressConfig:
    """Parse ``--compress`` specs: ``topk:0.01``, ``randk:0.05``,
    ``threshold:1e-3``, with an optional ``:noef`` suffix (EF off), or
    ``none``.  A CompressConfig passes through unchanged."""
    if isinstance(spec, CompressConfig):
        return spec
    if spec is None:
        return CompressConfig()
    parts = str(spec).split(":")
    kind = parts[0] or "none"
    if kind == "none":
        return CompressConfig()
    ef = True
    if parts and parts[-1] == "noef":
        ef = False
        parts = parts[:-1]
    if len(parts) != 2:
        raise ValueError(
            f"compress spec must look like 'topk:0.01[:noef]', got {spec!r}")
    val = float(parts[1])
    if kind == "threshold":
        return CompressConfig(kind=kind, threshold=val, ef=ef)
    return CompressConfig(kind=kind, density=val, ef=ef)


# ---------------------------------------------------------------------------
# the sparsifiers (traced; static shapes only)
# ---------------------------------------------------------------------------

def _keep_mask(cfg: CompressConfig, acc: jnp.ndarray,
               key: jnp.ndarray | None) -> jnp.ndarray:
    """Boolean keep-mask over the f32 accumulator ``acc`` [S]."""
    if cfg.kind == "topk":
        k = cfg.keep_count(acc.shape[0])
        _, idx = lax.top_k(jnp.abs(acc), k)
        return jnp.zeros(acc.shape, bool).at[idx].set(True)
    if cfg.kind == "threshold":
        return jnp.abs(acc) >= cfg.threshold
    if cfg.kind == "randk":
        assert key is not None
        return jax.random.uniform(key, acc.shape) < cfg.density
    raise ValueError(f"not a sparsifier: {cfg.kind!r}")


def compress_bucket(
    cfg: CompressConfig,
    payload: jnp.ndarray,
    residual: jnp.ndarray | None,
    *,
    key: jnp.ndarray | None = None,
):
    """EF-compress one flat bucket payload.

    Args:
      payload: the bucket's local gradient payload [S] (any float dtype).
      residual: f32 [S] error-feedback memory, or None when ``cfg.ef`` is
          off (plain lossy compression).
      key: PRNG key (randk only), deterministic in (seed, step, bucket).

    Returns ``(sent, new_residual, density)``: the sparsified payload in
    the input dtype (zeros off the mask — downstream schemes re-encode),
    the updated residual (None iff ``residual`` was None), and the traced
    post-compression local density d(1) = nnz / S.

    EF invariant: ``sent + new_residual == payload + residual`` exactly in
    f32 — compression moves information into the residual, never drops it.
    The subtraction uses the *dtype-cast* sent values so what is carried
    forward is exactly what the wire did not deliver.
    """
    acc = payload.astype(jnp.float32)
    if residual is not None:
        acc = acc + residual
    mask = _keep_mask(cfg, acc, key)
    sent = jnp.where(mask, acc, 0.0).astype(payload.dtype)
    new_residual = None
    if residual is not None:
        new_residual = acc - sent.astype(jnp.float32)
    density = jnp.mean(mask.astype(jnp.float32))
    return sent, new_residual, density


def compress_profile(
    cfg: CompressConfig, size: int, vw: int = 1
) -> costmodel.SparsityProfile:
    """Offline worst-case profile of a compressed bucket: the configured
    keep-density with no-overlap densification (the adversarial case for
    Zen's pull) — what ``choose_scheme`` uses before measurements exist."""
    return costmodel.worst_case_profile(size, cfg.density, vw=vw)


def measured_profile(
    size: int, d1: float, dn: float, n: int, vw: int = 1
) -> costmodel.SparsityProfile:
    """Profile from the two measured densification points the runtime
    reports: d(1) (local, post-compression) and d(n) (post-aggregation).
    Intermediate i interpolate linearly — only d(1) and d(n) enter the
    zen/dense volume formulas, so the interior never decides a scheme."""
    d1 = float(min(max(d1, 0.0), 1.0))
    dn = float(min(max(dn, d1), 1.0))

    def d(i: int) -> float:
        if n <= 1:
            return d1
        t = (min(max(i, 1), n) - 1) / (n - 1)
        return d1 + (dn - d1) * t

    return costmodel.SparsityProfile(M=size, d=d, s=lambda k: 1.0, vw=vw)


# ---------------------------------------------------------------------------
# adaptive density control (host-side feedback loop)
# ---------------------------------------------------------------------------

DENSITY1_KEY = "sync/ef_density1[{key}]"
DENSITYN_KEY = "sync/ef_densityN[{key}]"


class DensityController:
    """Feed measured post-compression density back into scheme selection.

    The bucket plan's schemes are static (they size buffers and pick
    collectives at trace time), but the density top-k/threshold actually
    produces drifts during training — gradients concentrate, thresholds
    bite differently, EF residuals change the effective distribution.
    The controller closes the loop from the *host* side:

        stats = train_step(...)            # sync/ef_density* metrics
        controller.observe(stats)          # EMA update
        if controller.drifted():           # choose_scheme disagrees
            profiles = controller.profiles()
            ...rebuild GradSync / program with profiles...  # recompile

    Replanning recompiles the step, so callers rate-limit it
    (``--replan-every`` in ``launch/train.py``).  Bucket *boundaries*
    never depend on schemes or profiles, so keys and residual shapes are
    stable across replans — optimizer state carries over untouched.
    """

    def __init__(
        self,
        bucket_sizes: dict[str, int],
        schemes: dict[str, str],
        n: int,
        *,
        ema: float = 0.8,
        threshold: float = 1.0,
        topology=None,
        calib=None,
    ):
        """``bucket_sizes``/``schemes``: per compressed-bucket key (from
        ``GradSync.compressed_buckets()``).  ``n`` is the sync world size;
        ``threshold`` mirrors ``SyncConfig.auto_threshold``.  On a
        hierarchical topology pass ``topology=gradsync.topology`` so the
        re-run decision uses the same α-β plan space (and plan tags) as
        the live bucket plan — an int-``n`` controller would recommend
        flat tags that never match ``hier(...)`` schemes and replan
        forever.  ``calib`` (a ``costmodel.CalibrationTable``, e.g.
        ``gradsync.calib``) makes the re-run decision encode-cost-aware,
        matching the live plan's pricing (DESIGN.md §11)."""
        self.sizes = dict(bucket_sizes)
        self.current = dict(schemes)
        self.n = max(n, 2)
        self.topology = topology
        self.calib = calib
        self.ema = float(ema)
        self.threshold = float(threshold)
        self._d1: dict[str, float] = {}
        self._dn: dict[str, float] = {}

    def observe(self, stats: dict) -> None:
        """Fold one step's metrics (host floats or 0-d arrays) into the
        per-bucket density EMAs.  Unknown keys are ignored, so the whole
        metrics dict can be passed as-is."""
        for key in self.sizes:
            for store, pattern in ((self._d1, DENSITY1_KEY),
                                   (self._dn, DENSITYN_KEY)):
                v = stats.get(pattern.format(key=key))
                if v is None:
                    continue
                v = float(v)
                old = store.get(key)
                store[key] = v if old is None else (
                    self.ema * old + (1 - self.ema) * v)

    def profiles(self) -> dict[str, costmodel.SparsityProfile]:
        """Measured profiles for every bucket with observations — the
        dict to pass straight to ``GradSync(profiles=...)`` on replan."""
        out = {}
        for key, size in self.sizes.items():
            if key in self._d1 and key in self._dn:
                out[key] = measured_profile(
                    size, self._d1[key], self._dn[key], self.n)
        return out

    def schemes(self) -> dict[str, str]:
        """choose_scheme on the measured profile per bucket; buckets with
        no observations yet keep their current scheme.  With a topology
        the recommendations are CommPlan tags (flat topologies included —
        the degenerate one reproduces the int-n picks exactly)."""
        out = dict(self.current)
        target = self.topology if self.topology is not None else self.n
        for key, prof in self.profiles().items():
            out[key] = costmodel.choose_scheme(
                prof, target, threshold=self.threshold, calib=self.calib)
        return out

    def drifted(self) -> dict[str, tuple[str, str]]:
        """``{key: (current, recommended)}`` where they disagree — truthy
        iff a replan would change at least one bucket's scheme."""
        rec = self.schemes()
        return {k: (self.current[k], rec[k])
                for k in self.current if rec[k] != self.current[k]}

    def rebase(self, schemes: dict[str, str]) -> None:
        """Record the schemes the freshly-built plan actually resolved
        (call after a replan so drift is measured against reality, not
        against the recommendation that triggered it)."""
        self.current = dict(schemes)

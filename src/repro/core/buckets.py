"""Gradient-pytree bucketing for overlap-scheduled synchronization.

DESIGN.md §7.  A ``BucketPlan`` partitions the flattened gradient pytree
into fixed-byte **buckets**, the unit at which the trainer emits sync ops
(`repro.train.schedule`):

* **Dense leaves** are flattened and fused: consecutive leaves of the same
  dtype are packed into one bucket while the bucket stays under
  ``bucket_bytes`` (a single leaf larger than the budget becomes its own
  oversized bucket — leaves are never split, so reassembly is a static
  slice/reshape).  One fused ``psum`` per bucket replaces one ``psum`` per
  leaf; because ``psum`` is elementwise, fusion is bit-exact.
* **Row-sparse leaves** (Zen's subject) are *never* fused or split: each is
  its own bucket.  The Zen layout (hash partitions, server offsets,
  bitmap width) is a pure function of the whole tensor's row count —
  splitting a table across buckets would need per-fragment layouts and
  would break the balanced-partition guarantee of Thm. 2 (DESIGN.md §7).
* ``bucket_bytes=None`` is the **monolithic fallback**: one bucket per
  leaf, no fusion — op-for-op the pre-bucketing gradient path, so every
  scheme stays bit-compatible with the PR-1 trainer.

The plan is built offline from abstract shapes (like ``ZenLayout``); the
traced work per step is only ``gather_bucket`` / ``scatter_bucket``
(concat + slice/reshape) around each bucket's sync op.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.schemes import SyncStats
from repro.core.topology import parse_plan

DENSE = "dense_fused"
SPARSE = "sparse"


def _all_dense(tag: str) -> bool:
    """Whether a plan tag moves only psum traffic: the bare 'dense' tag,
    or a hier plan whose every stage is dense — those buckets' words
    belong in ``sync/dense_words`` no matter the topology, so the
    dense/sparse volume split means the same thing at every node_size."""
    if tag == "dense":
        return True
    if tag.startswith("hier("):
        try:
            return all(s.scheme == "dense" for s in parse_plan(tag).stages)
        except ValueError:
            return False
    return False


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """One gradient leaf's home inside a bucket payload."""

    name: str            # '/'-joined tree path (GradSync naming)
    index: int           # position in jax.tree flatten order
    shape: tuple         # original leaf shape
    dtype: Any
    offset: int          # element offset inside the fused flat payload
    size: int            # element count


@dataclasses.dataclass(frozen=True)
class Bucket:
    """A unit of synchronization: one collective chain per bucket."""

    bid: int
    kind: str                     # DENSE | SPARSE
    # Resolved CommPlan tag (core/topology.py grammar).  On a flat
    # topology this is the bare scheme name — byte-identical to the
    # pre-topology tags; on a hierarchical topology 'auto' resolves to
    # tags like 'hier(zen@intra,dense@inter)' while explicit schemes
    # keep their bare name (expanded per-level at commit time).
    scheme: str
    slots: tuple[LeafSlot, ...]   # exactly 1 slot when kind == SPARSE
    nbytes: int
    # Compressor tag (core/sparsify.py spec string, e.g. 'topk:0.01') for
    # dense buckets whose payload is EF-sparsified before sync; 'none'
    # otherwise.  Row-sparse buckets are never compressed — they arrive
    # sparse, and the Zen layout already budgets their density.
    compress: str = "none"

    @property
    def size(self) -> int:
        return sum(s.size for s in self.slots)

    @property
    def key(self) -> str:
        """Stable identity for per-bucket state (EF residuals, density
        EMAs, Zen layouts): the first slot's leaf path.  Bucket
        *boundaries* depend only on shapes/dtypes/bucket_bytes — never on
        schemes, profiles, or the compressor — so keys survive replans."""
        return self.slots[0].name


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Offline partition of a gradient pytree into sync buckets."""

    buckets: tuple[Bucket, ...]
    n_leaves: int
    bucket_bytes: int | None

    @property
    def schemes(self) -> tuple[str, ...]:
        return tuple(b.scheme for b in self.buckets)

    def validate(self) -> None:
        """Every leaf in exactly one bucket; sparse buckets are singletons;
        fused dense buckets respect the byte budget (oversized leaves may
        stand alone)."""
        seen: set[int] = set()
        for b in self.buckets:
            for s in b.slots:
                if s.index in seen:
                    raise ValueError(f"leaf {s.name} assigned twice")
                seen.add(s.index)
            if b.kind == SPARSE and len(b.slots) != 1:
                raise ValueError(f"sparse bucket {b.bid} fuses leaves")
            if b.kind == SPARSE and b.compress != "none":
                raise ValueError(
                    f"row-sparse bucket {b.bid} must not be compressed")
            if (self.bucket_bytes is not None and b.kind == DENSE
                    and len(b.slots) > 1 and b.nbytes > self.bucket_bytes):
                raise ValueError(
                    f"fused bucket {b.bid} exceeds bucket_bytes")
        if len(seen) != self.n_leaves:
            raise ValueError(
                f"plan covers {len(seen)} of {self.n_leaves} leaves")


def leaf_path_str(path) -> str:
    return "/".join(
        str(getattr(p, "key", getattr(p, "idx", p))) for p in path
    )


def _leaf_nbytes(leaf) -> int:
    return int(leaf.size) * jnp.dtype(leaf.dtype).itemsize


def make_bucket_plan(
    grad_shapes: Any,
    is_sparse: Callable[[str], bool],
    bucket_bytes: int | None,
    sparse_scheme: Callable[[str, Any], str],
    dense_scheme: str = "dense",
    compress: str = "none",
    compressed_scheme: Callable[[str, int], str] | None = None,
) -> BucketPlan:
    """Build the plan from abstract grad shapes (offline, untraced).

    ``sparse_scheme(name, leaf)`` resolves the per-tensor scheme for a
    row-sparse leaf (the 'auto' cost-model decision lives in the caller);
    dense buckets use ``dense_scheme`` — unless ``compress`` is a
    sparsifier tag (core/sparsify.py), in which case every dense bucket
    is tagged with it and its scheme comes from
    ``compressed_scheme(key, size)`` (the caller's cost-model decision on
    the *post-compression* density profile).
    """
    if bucket_bytes is not None and bucket_bytes <= 0:
        raise ValueError(f"bucket_bytes must be positive, got {bucket_bytes}")
    leaves = jax.tree_util.tree_flatten_with_path(grad_shapes)[0]
    buckets: list[Bucket] = []
    pend: list[LeafSlot] = []   # dense leaves awaiting fusion
    pend_bytes = 0

    def flush():
        nonlocal pend, pend_bytes
        if pend:
            scheme = dense_scheme
            if compress != "none" and compressed_scheme is not None:
                scheme = compressed_scheme(
                    pend[0].name, sum(s.size for s in pend))
            buckets.append(Bucket(
                bid=len(buckets), kind=DENSE, scheme=scheme,
                slots=tuple(pend), nbytes=pend_bytes, compress=compress))
            pend, pend_bytes = [], 0

    for i, (path, leaf) in enumerate(leaves):
        name = leaf_path_str(path)
        size = int(leaf.size)
        nbytes = _leaf_nbytes(leaf)
        if is_sparse(name):
            flush()
            buckets.append(Bucket(
                bid=len(buckets), kind=SPARSE,
                scheme=sparse_scheme(name, leaf),
                slots=(LeafSlot(name, i, tuple(leaf.shape), leaf.dtype,
                                0, size),),
                nbytes=nbytes))
            continue
        fits = (bucket_bytes is not None and pend
                and pend[0].dtype == leaf.dtype
                and pend_bytes + nbytes <= bucket_bytes)
        if not fits:
            flush()
        pend.append(LeafSlot(
            name, i, tuple(leaf.shape), leaf.dtype,
            offset=sum(s.size for s in pend), size=size))
        pend_bytes += nbytes
        if bucket_bytes is None or pend_bytes >= bucket_bytes:
            flush()
    flush()
    plan = BucketPlan(buckets=tuple(buckets), n_leaves=len(leaves),
                      bucket_bytes=bucket_bytes)
    plan.validate()
    return plan


# ---------------------------------------------------------------------------
# payload assembly / disassembly (the only traced code in this module)
# ---------------------------------------------------------------------------

def gather_bucket(bucket: Bucket, flat_leaves: list) -> jnp.ndarray:
    """Assemble a bucket's payload from the flat leaf list.

    Sparse buckets pass their single leaf through unchanged (the scheme
    needs the [rows, d] structure); dense buckets are a flat concat."""
    if bucket.kind == SPARSE:
        return flat_leaves[bucket.slots[0].index]
    parts = [flat_leaves[s.index].reshape(-1) for s in bucket.slots]
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts)


def scatter_bucket(bucket: Bucket, payload: jnp.ndarray, out: list) -> None:
    """Write a synced payload back into the flat leaf list ``out``."""
    if bucket.kind == SPARSE:
        out[bucket.slots[0].index] = payload
        return
    for s in bucket.slots:
        out[s.index] = payload[s.offset:s.offset + s.size].reshape(s.shape)


# ---------------------------------------------------------------------------
# SyncStats reduction across buckets
# ---------------------------------------------------------------------------

def reduce_stats(
    plan: BucketPlan, per_bucket: list[SyncStats],
    extra: dict[str, jnp.ndarray] | None = None,
) -> dict[str, jnp.ndarray]:
    """Reduce per-bucket SyncStats into the trainer's metric dict.

    Keeps the monolithic path's keys (sparse_sent_words / overflow /
    dense_words) so dashboards and the multi-device tests are unchanged,
    and adds per-scheme bucket tags — static plan facts reported as
    constants so they survive the pmean over data.  ``dense_words``
    counts the fused-psum buckets; everything synchronized with a sparse
    scheme — row-sparse leaves AND compressed dense buckets — lands in
    ``sparse_sent_words`` (for uncompressed plans the split is identical
    to the historical by-kind accounting, because dense buckets always
    carried scheme='dense' there).  ``extra`` merges caller-supplied
    per-bucket metrics (e.g. the EF density measurements)."""
    sent = jnp.float32(0.0)
    dense_words = jnp.float32(0.0)
    overflow = jnp.int32(0)
    tags: dict[str, int] = {}
    n_compressed = 0
    level_words: list = []
    for b, st in zip(plan.buckets, per_bucket):
        overflow = overflow + st.overflow
        if b.kind == SPARSE or not _all_dense(b.scheme):
            sent = sent + st.sent_words
        else:
            dense_words = dense_words + st.sent_words
        tags[b.scheme] = tags.get(b.scheme, 0) + 1
        n_compressed += b.compress != "none"
        # hierarchical plans tag wire words by topology level (fastest
        # first); accumulate a whole-step per-level split
        for i, w in enumerate(getattr(st, "by_level", ()) or ()):
            while len(level_words) <= i:
                level_words.append(jnp.float32(0.0))
            level_words[i] = level_words[i] + w
    stats = {
        "sync/sparse_sent_words": sent,
        "sync/overflow": overflow,
        "sync/dense_words": dense_words,
        "sync/n_buckets": jnp.float32(len(plan.buckets)),
    }
    if len(level_words) >= 2:
        stats["sync/intra_words"] = level_words[0]
        stats["sync/inter_words"] = level_words[-1]
    if n_compressed:
        stats["sync/compressed_buckets"] = jnp.float32(n_compressed)
    for scheme, count in sorted(tags.items()):
        stats[f"sync/buckets[{scheme}]"] = jnp.float32(count)
    stats.update(extra or {})
    return stats

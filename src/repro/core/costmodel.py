"""Analytical communication-time models (§2.3.3, Fig. 7, Appendix B).

Each model returns the per-GPU *receive volume in FP32 words*; communication
time is ``volume / B``.  Results are usually normalized to ``dense`` — the
ring-allreduce volume — reproducing Fig. 7's y-axis exactly.

Conventions (matching Appendix B):
  * COO transmits 2 words per non-zero (index + value).
  * ``d(i)`` is the density after aggregating tensors from ``i`` workers
    (``d(1) = d_G``); the densification curve comes either from measured masks
    (`profile_from_masks`) or an analytic overlap model.
  * ``s(i)`` is the skewness ratio with ``i`` partitions (Def. 5).
"""
from __future__ import annotations

import dataclasses
import json
import math
from collections.abc import Mapping
from typing import Callable

import numpy as np

from repro.core import metrics
from repro.core import registry as _registry
from repro.core.registry import BALANCED_BINS
from repro.core.topology import (
    CommPlan,
    Level,
    Topology,
    flat_plan,
    hier_plan,
)


@dataclasses.dataclass(frozen=True)
class SparsityProfile:
    """Everything the cost models need to know about a workload's sparsity."""

    M: int                      # sparsity units (elements, or rows if vw > 1)
    d: Callable[[int], float]   # densification curve d(i), i >= 1
    s: Callable[[int], float]   # skewness curve s(n)
    block: int = 256            # OmniReduce block size
    block_density: Callable[[int], float] | None = None  # nonzero-block frac after i-agg
    # bottleneck partition's nonzero-block fraction (within that partition),
    # as a function of (i aggregated workers, n partitions)
    block_max: Callable[[int, int], float] | None = None
    # value width: FP32 words per sparsity unit — 1 for element-sparse (the
    # paper's setting), d for row-sparse embedding tables whose unit is an
    # embedding row.  COO then costs (1 + vw) words per non-zero and dense /
    # value-only terms scale by vw; every formula reduces to App. B at vw=1.
    vw: int = 1


def profile_from_masks(masks: np.ndarray, block: int = 256) -> SparsityProfile:
    """Measure d(i), s(n), and block density curves from [n, M] bool masks."""
    masks = np.asarray(masks)
    n, M = masks.shape
    d_curve = {}
    blk_curve = {}
    agg_cache = {}
    for i in range(1, n + 1):
        agg = masks[:i].any(axis=0)
        agg_cache[i] = agg
        d_curve[i] = float(agg.mean())
        nb = M // block
        blk = agg[: nb * block].reshape(nb, block).any(axis=1)
        blk_curve[i] = float(blk.mean())
    mask0 = masks[0]

    def block_max(i: int, parts: int) -> float:
        """Bottleneck partition's nonzero-block fraction (OmniReduce's
        aggregator hot spot)."""
        agg = agg_cache[min(max(i, 1), n)]
        nb = M // block
        blk = agg[: nb * block].reshape(nb, block).any(axis=1)
        kk = 1 << max(0, (parts - 1).bit_length())
        while nb % kk:
            kk //= 2
        per = blk.reshape(kk, nb // kk).mean(axis=1)
        return float(per.max())

    def s(k: int) -> float:
        kk = 1 << max(0, (k - 1).bit_length())  # nearest pow2 >= k
        while M % kk:
            kk //= 2
        return float(metrics.skewness_ratio(mask0, kk))

    return SparsityProfile(
        M=M,
        d=lambda i: d_curve[min(max(i, 1), n)],
        s=s,
        block=block,
        block_density=lambda i: blk_curve[min(max(i, 1), n)],
        block_max=block_max,
    )


# --- volumes (FP32 words received per GPU) ---------------------------------
# Each formula is App. B with the COO word count 2 generalized to (1 + vw)
# and dense / value-only terms scaled by vw (see SparsityProfile.vw).

def dense_allreduce(p: SparsityProfile, n: int) -> float:
    """Ring allreduce: reduce-scatter + all-gather."""
    return 2 * (n - 1) / n * p.M * p.vw


def agsparse(p: SparsityProfile, n: int) -> float:
    """AllGather of COO sparse tensors (one-shot, centralization)."""
    return (1 + p.vw) * (n - 1) * p.d(1) * p.M


def sparcml(p: SparsityProfile, n: int) -> float:
    """SSAR_Recursive_double: log n stages of pairwise COO exchange with
    incremental aggregation; stage i exchanges density d(2^(i-1))."""
    stages = int(math.log2(n))
    return sum((1 + p.vw) * p.d(2 ** (i - 1)) * p.M
               for i in range(1, stages + 1))


def sparse_ps(p: SparsityProfile, n: int) -> float:
    """Even-range partitioning PS: skew-penalized push and pull (App. B.1):
    2 (n-1) s^n (d_G + d_G^n) M / n."""
    return (1 + p.vw) * (n - 1) * p.s(n) * (p.d(1) + p.d(n)) * p.M / n


def omnireduce(p: SparsityProfile, n: int) -> float:
    """Block-format PS. Non-zero blocks carry ``block`` values + 1 id word.
    The bottleneck aggregator receives the hottest partition's blocks from
    every worker (push) and broadcasts its aggregated blocks (pull)."""
    # wire words per gradient in a non-zero block
    w = (p.block * p.vw + 1) / p.block
    if p.block_max is not None:
        push = (n - 1) * p.block_max(1, n) * w * p.M / n
        pull = (n - 1) * p.block_max(n, n) * w * p.M / n
        return push + pull
    assert p.block_density is not None
    push = (n - 1) * p.s(n) * p.block_density(1) * w * p.M / n
    pull = (n - 1) * p.s(n) * p.block_density(n) * w * p.M / n
    return push + pull


def balanced_parallelism(p: SparsityProfile, n: int) -> float:
    """Theorem 1.2's optimal scheme with COO (skew = 1 by construction):
    2 (n-1)(d_G + d_G^n) M / n."""
    return (1 + p.vw) * (n - 1) * (p.d(1) + p.d(n)) * p.M / n


def balanced(p: SparsityProfile, n: int) -> float:
    """Executable Ok-Topk-style balanced split-and-exchange
    (``schemes.balanced_sync``): the histogram rebalance makes skew 1 by
    construction, so push + pull are exactly ``balanced_parallelism``'s
    optimal COO terms — note no ``s(n)`` factor, unlike ``sparse_ps`` —
    plus the B-bin boundary histogram's f32 allreduce."""
    bins = min(p.M, BALANCED_BINS)
    return balanced_parallelism(p, n) + 2 * (n - 1) / n * bins


def zen(p: SparsityProfile, n: int) -> float:
    """Balanced Parallelism + hash bitmap on Pull (§3.2.2):
    push COO (low density), pull values + M/32-word bitmap (Thm. 3)."""
    push = (1 + p.vw) * (n - 1) * p.d(1) * p.M / n
    pull = (n - 1) / n * (p.d(n) * p.M * p.vw + p.M / 32)
    return push + pull


def lower_bound(p: SparsityProfile, n: "int | Topology") -> float:
    """§4.1 footnote 3: receive the aggregated non-zeros of the other n-1
    workers, index-free: d_G^(n-1) M.  With a ``Topology`` the floor is
    β-weighted per level: every plan must move at least the flat floor's
    words over each level's links (see ``plan_times``)."""
    if isinstance(n, Topology):
        lb, k = 0.0, 1
        for lvl in n.levels:
            if lvl.size > 1:
                lb += lvl.beta * lower_bound(merged_profile(p, k), lvl.size)
            k *= lvl.size
        return lb
    return p.d(n - 1) * p.M * p.vw if n > 1 else 0.0


class _RegistryView(Mapping):
    """Live mapping {scheme name -> registered fn}: the historical
    ``SCHEMES`` / ``ROUNDS`` dict API, now backed by the scheme registry
    (single registration surface — repro.core.registry)."""

    def __init__(self, attr: str):
        self._attr = attr

    def __getitem__(self, name: str) -> Callable:
        return getattr(_registry.get_scheme(name), self._attr)

    def __iter__(self):
        return iter(_registry.registered_schemes())

    def __len__(self) -> int:
        return len(_registry.registered_schemes())


# Volume formulas per scheme name (words received per GPU), and the
# message-round counts — the α (latency) term of the α-β link model.  A
# ring allreduce is 2(n-1) rounds; an all_gather ring n-1; a2a push +
# all_gather pull schemes pay both; recursive doubling log2 n; balanced
# additionally pays its histogram allreduce.  Both mappings are views
# over the registry (registrations at the bottom of this module).
SCHEMES: Mapping[str, Callable[[SparsityProfile, int], float]] = \
    _RegistryView("volume_fn")
ROUNDS: Mapping[str, Callable[[int], float]] = _RegistryView("rounds_fn")


# --- zenlint wire contracts (repro.analysis; DESIGN.md §13) ----------------
# wire_words_fn(M, n, kw): the EXACT per-device wire words the lowered
# program emits at stage kwargs ``kw`` (value width 1) — capacity-shaped,
# unlike volume_fn's density-shaped estimate.  The zenlint driver compares
# these against trip-weighted HLO collective bytes per replica-group size;
# they must mirror the collectives in core/schemes.py op for op.

def _wire_dense(M: int, n: int, kw: dict) -> float:
    return 2.0 * (n - 1) / n * M


def _wire_zen(M: int, n: int, kw: dict) -> float:
    lo = kw["layout"]
    cp = lo.r1 + lo.r2  # a2a row width == pull compaction budget
    if kw.get("use_hash_bitmap", True):
        return float((n - 1) * (3 * cp + lo.cap_bitmap_words))
    return float((n - 1) * 4 * cp)


def _wire_agsparse(M: int, n: int, kw: dict) -> float:
    return 2.0 * (n - 1) * kw["capacity"]


def _wire_sparcml(M: int, n: int, kw: dict) -> float:
    return sum(2.0 * min(kw["capacity"] * (2 ** s) * 2, M)
               for s in range(int(math.log2(n))))


def _wire_sparse_ps(M: int, n: int, kw: dict) -> float:
    return 2.0 * (n - 1) * (kw["cap_push"] + kw["cap_pull"])


def _wire_omnireduce(M: int, n: int, kw: dict) -> float:
    return float((n - 1) * (kw["cap_push"] + kw["cap_pull"])
                 * (1 + kw["block"]))


def _wire_balanced(M: int, n: int, kw: dict) -> float:
    B = min(M, kw.get("bins") or BALANCED_BINS)
    cap_push = kw["cap_push"]
    cap_pull = kw.get("cap_pull") or cap_push
    return 2.0 * (n - 1) / n * B + 2.0 * (n - 1) * (cap_push + cap_pull)


# --- scheme registrations (the single surface — DESIGN.md §12) -------------
# Order matters twice: ``plan_candidates`` keeps registration order, so
# dense must come first (argmin ties resolve dense) and balanced last
# (a new candidate must not steal exact ties from the historical set).
# ``sync_fn`` strings resolve lazily on repro.core.schemes: this module
# stays importable without jax (analysis-only rigs).
#
# lint_caps_fn sizes a stage so a FULLY DENSE [*, M] payload exactly
# saturates every buffer — that is what makes the SyncStats claim equal
# the wire bytes (R2's ==) for lint_saturable schemes.  Zen's buffers are
# r1_factor-overprovisioned by design (claim <= wire, never ==), so it is
# not saturable and lints at its working density instead.

_registry.register_scheme(
    "dense", "dense_sync", dense_allreduce, lambda n: 2.0 * (n - 1),
    plan_candidate=True,
    wire_words_fn=_wire_dense, expected_collectives=("all-reduce",),
    lint_saturable=True, lint_caps_fn=lambda M, n: {})
_registry.register_scheme(
    "zen", "zen_sync", zen, lambda n: 2.0 * (n - 1),
    stage_args=("layout", "use_hash_bitmap", "backend", "interpret", "fused",
                "fused_commit"),
    required_args=("layout",), plan_candidate=True,
    wire_words_fn=_wire_zen,
    expected_collectives=("all-to-all", "all-gather"),
    lint_saturable=False, lint_density=0.25,
    # the fused-commit megakernel route must satisfy the same R1-R5
    # invariants with the same wire words (fusing compute may not change
    # a single transmitted word)
    lint_routes=(("fused-commit", (("backend", "pallas"), ("fused", True),
                                   ("fused_commit", True))),))
_registry.register_scheme(
    "agsparse", "agsparse_sync", agsparse, lambda n: float(n - 1),
    stage_args=("capacity",), required_args=("capacity",),
    plan_candidate=True,
    wire_words_fn=_wire_agsparse, expected_collectives=("all-gather",),
    lint_saturable=True, lint_caps_fn=lambda M, n: {"capacity": M})
_registry.register_scheme(
    "sparcml", "sparcml_sync", sparcml,
    lambda n: float(math.ceil(math.log2(max(n, 2)))),
    stage_args=("capacity",), required_args=("capacity",), needs_n=True,
    plan_candidate=True, feasible_fn=lambda n, M: n & (n - 1) == 0,
    wire_words_fn=_wire_sparcml,
    expected_collectives=("collective-permute",),
    lint_saturable=True, lint_caps_fn=lambda M, n: {"capacity": M})
_registry.register_scheme(
    "sparse_ps", "sparse_ps_sync", sparse_ps, lambda n: 2.0 * (n - 1),
    stage_args=("capacity", "cap_push", "cap_pull"),
    required_args=(("cap_push", "capacity"), ("cap_pull", "capacity")),
    arg_aliases=(("capacity", ("cap_push", "cap_pull")),),
    needs_n=True, feasible_fn=lambda n, M: M % n == 0,
    wire_words_fn=_wire_sparse_ps,
    expected_collectives=("all-to-all", "all-gather"),
    lint_saturable=True, lint_caps_fn=lambda M, n: {"capacity": M // n})
_registry.register_scheme(
    "omnireduce", "omnireduce_sync", omnireduce, lambda n: 2.0 * (n - 1),
    stage_args=("capacity", "cap_push", "cap_pull", "block"),
    required_args=(("cap_push", "capacity"), ("cap_pull", "capacity")),
    arg_aliases=(("capacity", ("cap_push", "cap_pull")),),
    arg_defaults=(("block", 8),), needs_n=True,
    wire_words_fn=_wire_omnireduce,
    expected_collectives=("all-to-all", "all-gather"),
    lint_saturable=True,
    lint_caps_fn=lambda M, n: {"block": 8, "capacity": M // n // 8})
_registry.register_scheme(
    "balanced", "balanced_sync", balanced, lambda n: 4.0 * (n - 1),
    stage_args=("capacity", "cap_push", "cap_pull", "bins"),
    required_args=(("cap_push", "capacity"),),
    arg_aliases=(("capacity", ("cap_push", "cap_pull")),),
    needs_n=True, plan_candidate=True,
    wire_words_fn=_wire_balanced,
    expected_collectives=("all-reduce", "all-to-all", "all-gather"),
    lint_saturable=True, lint_caps_fn=lambda M, n: {"capacity": M // n})
# analytic-only curves (no executable collective): Fig. 7's optimum and
# the information-theoretic floor
_registry.register_scheme(
    "balanced_parallelism", None, balanced_parallelism,
    lambda n: 2.0 * (n - 1))
_registry.register_scheme(
    "lower_bound", None, lower_bound, lambda n: 1.0)


# ---------------------------------------------------------------------------
# α-β times over a Topology (DESIGN.md §10)
# ---------------------------------------------------------------------------

def merged_profile(p: SparsityProfile, k: int) -> SparsityProfile:
    """The per-*node* profile after aggregating ``k`` workers inside a
    node: one node-level "worker" now carries density ``d(k)``, and i
    nodes together carry ``d(i*k)`` — the boundary semantics of the intra
    merge.  Skew and block curves shift the same way."""
    if k <= 1:
        return p
    return SparsityProfile(
        M=p.M,
        d=lambda i: p.d(max(i, 1) * k),
        s=p.s,
        block=p.block,
        block_density=(None if p.block_density is None
                       else (lambda i: p.block_density(max(i, 1) * k))),
        block_max=(None if p.block_max is None
                   else (lambda i, parts: p.block_max(max(i, 1) * k, parts))),
        vw=p.vw,
    )


def stage_time(scheme: str, p: SparsityProfile, level: Level) -> float:
    """α-β time (µs) of one plan stage: ``alpha * rounds + beta * words``.
    A size-1 level is free (nothing to synchronize)."""
    n = level.size
    if n <= 1:
        return 0.0
    return level.alpha * ROUNDS[scheme](n) + level.beta * SCHEMES[scheme](p, n)


def plan_time(plan: CommPlan, p: SparsityProfile, topo: Topology) -> float:
    """α-β time of a full CommPlan: stages run fastest level first, and
    each later stage sees the profile *merged* over every earlier level
    (capacity growth at the intra merge)."""
    t, k = 0.0, 1
    for stage in plan.stages:
        lvl = topo.levels[stage.level]
        t += stage_time(stage.scheme, merged_profile(p, k), lvl)
        k *= lvl.size
    return t


def _feasible(scheme: str, n: int, M: int) -> bool:
    """Whether a scheme can run at a level of size ``n`` (static shape /
    divisibility constraints, registered on each SchemeSpec)."""
    return _registry.get_scheme(scheme).feasible(n, M)


def candidate_plans(topo: Topology, M: int = 0) -> list[CommPlan]:
    """Every plan the planner considers, dense-first (so an argmin with
    ties resolves toward dense, matching ``choose_scheme``'s flat
    tie-break).  The candidate set is the registry's ``plan_candidate``
    schemes in registration order; sparse_ps / omnireduce register as
    non-candidates — they are the paper's imbalanced strawmen and carry
    divisibility constraints — so explicit tags can still request them,
    the planner just never picks them."""
    cands = _registry.plan_candidates()
    if topo.flat:
        n = topo.intra.size
        return [flat_plan(s) for s in cands if _feasible(s, n, M)]
    intra = [s for s in cands if _feasible(s, topo.intra.size, M)]
    inter = [s for s in cands if _feasible(s, topo.inter.size, M)]
    return [hier_plan(si, se) for si in intra for se in inter]


def plan_times(p: SparsityProfile, topo: Topology) -> dict[str, float]:
    """α-β time per candidate plan tag, plus the ``lower_bound`` floor
    (β-weighted per-level information minimum)."""
    out = {pl.tag(): plan_time(pl, p, topo) for pl in candidate_plans(topo, p.M)}
    out["lower_bound"] = lower_bound(p, topo)
    return out


def normalized_times(
    p: SparsityProfile, n: "int | Topology"
) -> dict[str, float]:
    """All schemes normalized to dense ring-allreduce (Fig. 7 y-axis).

    With an ``int`` (the historical signature) this is pure word volume.
    With a flat ``Topology`` the α-β times are normalized the same way —
    and on the *degenerate* topology (α=0, β=1) the result is exactly the
    int version.  With a two-level topology the keys are CommPlan tags
    (``hier(zen@intra,agsparse@inter)``, ...) normalized to the
    hierarchical dense plan."""
    if isinstance(n, Topology):
        topo = n
        if topo.flat:
            lvl = topo.intra
            base = stage_time("dense", p, lvl)
            return {name: stage_time(name, p, lvl) / base
                    for name in SCHEMES}
        times = plan_times(p, topo)
        base = times[hier_plan("dense", "dense").tag()]
        return {tag: t / base for tag, t in times.items()}
    base = dense_allreduce(p, n)
    return {name: fn(p, n) / base for name, fn in SCHEMES.items()}


# --- offline auto-scheme decision (runtime fallback, shared with Fig. 7) ----

def worst_case_profile(M: int, density: float, vw: int = 1) -> SparsityProfile:
    """Profile for a tensor whose per-step sparsity is only known by budget:
    no-overlap densification d(i) = min(i·d_G, 1) (the adversarial case for
    Zen's pull) and skew 1 (irrelevant to zen/dense)."""
    return SparsityProfile(
        M=M, d=lambda i: min(1.0, max(i, 1) * density), s=lambda n: 1.0, vw=vw)


def choose_plan(
    p: SparsityProfile, topo: Topology, *, threshold: float = 1.0,
    calib: "CalibrationTable | None" = None,
) -> CommPlan:
    """argmin of the α-β plan times over the candidate set, biased toward
    dense: a non-dense plan wins only when its time beats the all-dense
    plan by ``threshold`` (ties resolve to dense via candidate order).
    This is where densify-after-intra-aggregation falls out: when the
    merged density ``d(n_intra)`` crosses the dense/sparse break-even on
    the inter links, ``hier(zen@intra, dense@inter)`` (or all-dense)
    times below ``hier(zen@intra, zen@inter)`` and wins.

    With a ``calib`` table (DESIGN.md §11) each candidate additionally
    pays its *measured* per-stage encode overhead
    (``plan_encode_overhead``); the identity table adds exactly 0.0, so
    the decision degenerates bitwise to the analytic argmin
    (tests/test_calibration.py property-tests this)."""
    cands = candidate_plans(topo, p.M)

    def t(pl: CommPlan) -> float:
        tt = plan_time(pl, p, topo)
        if calib is not None:
            tt += plan_encode_overhead(calib, pl, p, topo)
        return tt

    times = {pl.tag(): t(pl) for pl in cands}
    dense_tag = cands[0].tag()
    best = min(cands, key=lambda pl: times[pl.tag()])
    if times[best.tag()] >= threshold * times[dense_tag]:
        return cands[0]
    return best


def choose_scheme(
    p: SparsityProfile, n: "int | Topology", *, threshold: float = 1.0,
    calib: "CalibrationTable | None" = None,
) -> str:
    """Per-tensor scheme choice from a (measured or worst-case) profile:
    'zen' iff its wire volume beats dense ring allreduce by ``threshold``.
    This is the decision the bucket planner applies tensor-by-tensor —
    scheme='auto' is per-leaf, never global (a high-density table falls
    back to dense without dragging genuinely sparse tables with it).

    With an ``int`` (or the degenerate flat topology) the decision is the
    historical volume comparison, bit-identical.  With a two-level
    ``Topology`` the returned tag is the α-β-optimal CommPlan's
    (``choose_plan``), e.g. ``hier(zen@intra,dense@inter)``.

    ``calib`` adds measured per-stage encode overhead to each side of the
    comparison (PacTrain-style: the decision reflects what the machine
    does, not just the wire).  Encode cost only ever flips zen -> dense
    (dense encodes for free), and ``calib=None`` / the identity table
    keep the historical decision bit-identical."""
    if isinstance(n, Topology):
        topo = n
        if not topo.flat:
            return choose_plan(p, topo, threshold=threshold,
                               calib=calib).tag()
        lvl = topo.intra
        if lvl.size < 2:
            return "dense"
        zt = stage_time("zen", p, lvl)
        dt = stage_time("dense", p, lvl)
        if calib is not None:
            zt += (calib.encode_us("zen", p.M * p.vw, p.d(1))
                   + calib.commit_us("zen", p.M * p.vw, p.d(1)))
            dt += (calib.encode_us("dense", p.M * p.vw, p.d(1))
                   + calib.commit_us("dense", p.M * p.vw, p.d(1)))
        return "zen" if zt < threshold * dt else "dense"
    if n < 2:
        return "dense"  # single worker: nothing to sync, dense psum is free
    z, de = zen(p, n), dense_allreduce(p, n)
    if calib is not None:
        # words -> µs at the measured dense rate, then add measured encode
        # overhead; beta > 0 and identity (beta=1, encode=0) preserve the
        # analytic order/threshold exactly.
        b = calib.beta_us_per_word(p.M * p.vw)
        z = z * b + (calib.encode_us("zen", p.M * p.vw, p.d(1))
                     + calib.commit_us("zen", p.M * p.vw, p.d(1)))
        de = de * b + (calib.encode_us("dense", p.M * p.vw, p.d(1))
                       + calib.commit_us("dense", p.M * p.vw, p.d(1)))
    return "zen" if z < threshold * de else "dense"


def zen_beats_dense(
    rows: int, d: int, n: int, *, density_budget: float,
    threshold: float = 1.0,
) -> bool:
    """The 'auto' scheme's per-leaf offline choice: sync a [rows, d] row-sparse
    leaf with Zen iff its worst-case wire volume beats dense ring allreduce by
    ``threshold``.  Built from the same ``zen`` / ``dense_allreduce`` formulas
    as the Fig. 7 analytics so the runtime fallback cannot drift from them.
    """
    p = worst_case_profile(rows, density_budget, vw=max(d, 1))
    return choose_scheme(p, n, threshold=threshold) == "zen"


# ---------------------------------------------------------------------------
# Measured-time calibration (DESIGN.md §11)
#
# The analytic α-β model prices the *wire*; it cannot see that zen's encode
# (hash + extract + pack) costs real device time while dense encodes for
# free.  A CalibrationTable holds measured per-stage times keyed by
# (backend, payload words, density); choose_scheme / choose_plan add the
# measured encode overhead to each candidate so the decision flips to dense
# exactly when encode cost eats the wire win (the PacTrain argument —
# PAPERS.md, arXiv 2505.18563).
# ---------------------------------------------------------------------------

# v2: commit_us became a DIRECT measurement (a commit-only probe over
# pre-computed encodes, per-worker share) instead of the v1 clamped
# residual max(zen_us - n*encode_us, 0), which collapsed to 0 whenever
# encode timing noise exceeded the commit share.  v1 tables are rejected
# on load (re-run the calibrator).
_CALIB_VERSION = 2

# entry keys every table row carries:
#   backend    "xla" | "pallas"        compute route measured
#   size       int, payload FP32 words (M * vw)
#   density    float, d(1) measured at
#   n          int, sync-axis size of the measurement
#   encode_us  float, one zen_encode of one worker's payload
#   commit_us  float, one worker's zen_commit share, measured directly:
#              simulate(zen_commit) over n pre-encoded workers / n
#   zen_us     float, full zen_sync end-to-end (n simulated workers)
#   dense_us   float, dense allreduce end-to-end (same rig)


@dataclasses.dataclass
class CalibrationTable:
    """Measured per-stage sync times, persisted as JSON (``--calib-file``).

    Lookups are nearest-neighbor in (log size, log density) with encode
    time scaled linearly in payload size (encode work is O(nnz) ⊆ O(M)).
    The *identity* table (no entries) prices encode at 0 µs and the wire
    at 1 µs/word — choose_scheme / choose_plan then degenerate bitwise to
    the analytic α-β decision (property-tested)."""

    entries: list = dataclasses.field(default_factory=list)
    meta: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def identity(cls) -> "CalibrationTable":
        """Zero encode overhead, unit wire rate: the analytic model."""
        return cls(entries=[], meta={"identity": True})

    # --- persistence -------------------------------------------------------
    def save(self, path) -> None:
        blob = {"version": _CALIB_VERSION, "meta": self.meta,
                "entries": self.entries}
        with open(path, "w") as f:
            json.dump(blob, f, indent=2, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path) -> "CalibrationTable":
        with open(path) as f:
            blob = json.load(f)
        if blob.get("version") != _CALIB_VERSION:
            raise ValueError(
                f"calibration table {path}: version {blob.get('version')!r}"
                f" != {_CALIB_VERSION} (re-run the calibrator)")
        return cls(entries=blob["entries"], meta=blob.get("meta", {}))

    # --- lookups -----------------------------------------------------------
    def _nearest(self, size: float, density: float | None = None):
        if not self.entries:
            return None
        size = max(float(size), 1.0)

        def dist(e):
            ds = abs(math.log(max(e["size"], 1) / size))
            if density is None:
                return ds
            dd = abs(math.log(max(e["density"], 1e-9)
                              / max(density, 1e-9)))
            return ds + dd

        return min(self.entries, key=dist)

    def encode_us(self, scheme: str, size: float, density: float) -> float:
        """Measured local-encode overhead (µs) of ``scheme`` on a payload
        of ``size`` words at density ``density``.  Dense (a bare psum) and
        any unmeasured scheme encode for free; zen pays the nearest
        measurement scaled linearly in size."""
        if scheme != "zen":
            return 0.0
        e = self._nearest(size, density)
        if e is None:
            return 0.0
        return float(e["encode_us"]) * (max(float(size), 1.0)
                                        / max(e["size"], 1))

    def commit_us(self, scheme: str, size: float, density: float) -> float:
        """Measured per-worker commit overhead (µs): push + server
        aggregation + pull decode beyond the wire itself.  Dense commits
        for free (the psum IS the wire); zen pays the nearest direct
        commit-probe measurement scaled linearly in size (aggregation and
        decode work are O(capacity) ⊆ O(M))."""
        if scheme != "zen":
            return 0.0
        e = self._nearest(size, density)
        if e is None:
            return 0.0
        return float(e.get("commit_us", 0.0)) * (max(float(size), 1.0)
                                                 / max(e["size"], 1))

    def beta_us_per_word(self, size: float) -> float:
        """Measured wire rate (µs per FP32 word) from the dense-allreduce
        measurement nearest in size; 1.0 (the analytic unit) when empty."""
        e = self._nearest(size)
        if e is None:
            return 1.0
        words = dense_allreduce(
            worst_case_profile(int(e["size"]), 1.0), int(e["n"]))
        return float(e["dense_us"]) / max(words, 1.0)


def plan_encode_overhead(
    calib: CalibrationTable, plan: CommPlan, p: SparsityProfile,
    topo: Topology,
) -> float:
    """Measured compute overhead (µs) a CommPlan pays beyond the wire:
    each non-trivial stage encodes its (merged) payload once before its
    collectives and pays its per-worker commit (server aggregation + pull
    decode) once after them."""
    t, k = 0.0, 1
    for stage in plan.stages:
        lvl = topo.levels[stage.level]
        if lvl.size > 1:
            mp = merged_profile(p, k)
            t += (calib.encode_us(stage.scheme, mp.M * mp.vw, mp.d(1))
                  + calib.commit_us(stage.scheme, mp.M * mp.vw, mp.d(1)))
        k *= lvl.size
    return t


class CostCalibrator:
    """Measures real encode / commit / dense times on this machine and
    returns a CalibrationTable (DESIGN.md §11).

    Per (size, density) point it times, jitted and blocked-until-ready:
      * ``zen_encode`` of one worker's payload       -> encode_us
      * ``zen_commit`` over n PRE-ENCODED workers    -> commit_us (per
        worker: measured total / n — on a real mesh each device commits
        its share concurrently)
      * ``simulate(zen_sync)`` over n workers        -> zen_us
      * ``simulate(dense_sync)`` over n workers      -> dense_us
    The commit probe feeds eagerly materialized encodes into a jitted
    vmap of ``zen_commit`` alone, so commit cost is a direct measurement
    — not the v1 residual ``max(zen_us - n * encode_us, 0)``, whose clamp
    hid the commit share whenever encode timing noise exceeded it.
    Imports of jax / schemes are deferred so the cost model stays
    importable on analysis-only rigs.
    """

    def __init__(self, *, backend: str = "xla", n: int = 4,
                 sizes: tuple = (1 << 12, 1 << 14, 1 << 16),
                 densities: tuple = (0.01, 0.1),
                 iters: int = 5, warmup: int = 2, seed: int = 0):
        if n < 2:
            raise ValueError("CostCalibrator needs n >= 2 (a sync axis)")
        self.backend = backend
        self.n = n
        self.sizes = tuple(int(s) for s in sizes)
        self.densities = tuple(float(d) for d in densities)
        self.iters = iters
        self.warmup = warmup
        self.seed = seed

    def _time_us(self, fn, *args) -> float:
        """min-of-iters wall time in µs (jax dispatch + compute)."""
        import time as _time

        import jax

        for _ in range(self.warmup):
            jax.block_until_ready(fn(*args))
        best = math.inf
        for _ in range(self.iters):
            t0 = _time.perf_counter()
            jax.block_until_ready(fn(*args))
            best = min(best, _time.perf_counter() - t0)
        return best * 1e6

    def measure(self) -> CalibrationTable:
        import functools

        import jax
        import numpy as np_

        from repro.core import schemes

        entries = []
        rng = np_.random.default_rng(self.seed)
        for size in self.sizes:
            for density in self.densities:
                budget = min(0.5, max(4.0 * density, 8.0 / size))
                layout = schemes.make_zen_layout(
                    size, self.n, density_budget=budget)
                masks = rng.uniform(size=(self.n, size)) < density
                g = jax.numpy.asarray(
                    rng.standard_normal((self.n, size)).astype("float32")
                    * masks)
                enc = jax.jit(functools.partial(
                    schemes.zen_encode, layout=layout,
                    backend=self.backend))
                encode_us = self._time_us(enc, g[0])
                # commit-only probe: encodes are materialized OUTSIDE the
                # timed function, so the measurement isolates push +
                # aggregation + pull decode (direct, not a residual)
                encs = jax.block_until_ready(
                    jax.jit(jax.vmap(functools.partial(
                        schemes.zen_encode, layout=layout,
                        backend=self.backend)))(g))
                commit_run = jax.jit(jax.vmap(functools.partial(
                    schemes.zen_commit, axis=schemes.AXIS, layout=layout,
                    backend=self.backend), axis_name=schemes.AXIS))
                commit_us = self._time_us(commit_run, encs, g) / self.n
                zen_run = jax.jit(functools.partial(
                    schemes.simulate, schemes.zen_sync, layout=layout,
                    backend=self.backend))
                zen_us = self._time_us(zen_run, g)
                dense_run = jax.jit(functools.partial(
                    schemes.simulate, schemes.dense_sync))
                dense_us = self._time_us(dense_run, g)
                entries.append({
                    "backend": self.backend,
                    "size": size,
                    "density": density,
                    "n": self.n,
                    "encode_us": encode_us,
                    "commit_us": commit_us,
                    "zen_us": zen_us,
                    "dense_us": dense_us,
                })
        meta = {
            "backend": self.backend,
            "n": self.n,
            "device": str(jax.devices()[0]),
            "jax": jax.__version__,
        }
        return CalibrationTable(entries=entries, meta=meta)


def _main(argv=None) -> None:
    """``python -m repro.core.costmodel``: run the calibrator, persist the
    table, and print where the measured decision differs from the analytic
    one (the flip points)."""
    import argparse

    ap = argparse.ArgumentParser(
        prog="repro.core.costmodel",
        description="CostCalibrator: measure per-stage encode/commit/dense "
                    "times on this machine and write a --calib-file table "
                    "for launch/train.py and launch/dryrun.py")
    ap.add_argument("--calib-file", required=True)
    ap.add_argument("--backend", default="xla", choices=["xla", "pallas"])
    ap.add_argument("--n", type=int, default=4)
    ap.add_argument("--sizes", default="4096,16384,65536",
                    help="comma-separated payload sizes (FP32 words)")
    ap.add_argument("--densities", default="0.01,0.1")
    ap.add_argument("--iters", type=int, default=5)
    args = ap.parse_args(argv)

    cal = CostCalibrator(
        backend=args.backend, n=args.n,
        sizes=tuple(int(s) for s in args.sizes.split(",")),
        densities=tuple(float(d) for d in args.densities.split(",")),
        iters=args.iters)
    table = cal.measure()
    table.save(args.calib_file)
    print(f"wrote {len(table.entries)} entries -> {args.calib_file} "
          f"(device: {table.meta['device']})")
    for e in table.entries:
        p = worst_case_profile(e["size"], e["density"])
        analytic = choose_scheme(p, e["n"])
        measured = choose_scheme(p, e["n"], calib=table)
        flip = "  <- FLIP" if analytic != measured else ""
        print(f"  size={e['size']:>7} d={e['density']:<5} "
              f"encode={e['encode_us']:>9.1f}us "
              f"commit={e['commit_us']:>9.1f}us zen={e['zen_us']:>9.1f}us "
              f"dense={e['dense_us']:>9.1f}us analytic={analytic} "
              f"measured={measured}{flip}")


if __name__ == "__main__":
    _main()

"""Analytical communication-time models (§2.3.3, Fig. 7, Appendix B).

Each model returns the per-GPU *receive volume in FP32 words*; communication
time is ``volume / B``.  Results are usually normalized to ``dense`` — the
ring-allreduce volume — reproducing Fig. 7's y-axis exactly.

Conventions (matching Appendix B):
  * COO transmits 2 words per non-zero (index + value).
  * ``d(i)`` is the density after aggregating tensors from ``i`` workers
    (``d(1) = d_G``); the densification curve comes either from measured masks
    (`profile_from_masks`) or an analytic overlap model.
  * ``s(i)`` is the skewness ratio with ``i`` partitions (Def. 5).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

from repro.core import metrics


@dataclasses.dataclass(frozen=True)
class SparsityProfile:
    """Everything the cost models need to know about a workload's sparsity."""

    M: int                      # sparsity units (elements, or rows if vw > 1)
    d: Callable[[int], float]   # densification curve d(i), i >= 1
    s: Callable[[int], float]   # skewness curve s(n)
    block: int = 256            # OmniReduce block size
    block_density: Callable[[int], float] | None = None  # nonzero-block frac after i-agg
    # bottleneck partition's nonzero-block fraction (within that partition),
    # as a function of (i aggregated workers, n partitions)
    block_max: Callable[[int, int], float] | None = None
    # value width: FP32 words per sparsity unit — 1 for element-sparse (the
    # paper's setting), d for row-sparse embedding tables whose unit is an
    # embedding row.  COO then costs (1 + vw) words per non-zero and dense /
    # value-only terms scale by vw; every formula reduces to App. B at vw=1.
    vw: int = 1


def profile_from_masks(masks: np.ndarray, block: int = 256) -> SparsityProfile:
    """Measure d(i), s(n), and block density curves from [n, M] bool masks."""
    masks = np.asarray(masks)
    n, M = masks.shape
    d_curve = {}
    blk_curve = {}
    agg_cache = {}
    for i in range(1, n + 1):
        agg = masks[:i].any(axis=0)
        agg_cache[i] = agg
        d_curve[i] = float(agg.mean())
        nb = M // block
        blk = agg[: nb * block].reshape(nb, block).any(axis=1)
        blk_curve[i] = float(blk.mean())
    mask0 = masks[0]

    def block_max(i: int, parts: int) -> float:
        """Bottleneck partition's nonzero-block fraction (OmniReduce's
        aggregator hot spot)."""
        agg = agg_cache[min(max(i, 1), n)]
        nb = M // block
        blk = agg[: nb * block].reshape(nb, block).any(axis=1)
        kk = 1 << max(0, (parts - 1).bit_length())
        while nb % kk:
            kk //= 2
        per = blk.reshape(kk, nb // kk).mean(axis=1)
        return float(per.max())

    def s(k: int) -> float:
        kk = 1 << max(0, (k - 1).bit_length())  # nearest pow2 >= k
        while M % kk:
            kk //= 2
        return float(metrics.skewness_ratio(mask0, kk))

    return SparsityProfile(
        M=M,
        d=lambda i: d_curve[min(max(i, 1), n)],
        s=s,
        block=block,
        block_density=lambda i: blk_curve[min(max(i, 1), n)],
        block_max=block_max,
    )


# --- volumes (FP32 words received per GPU) ---------------------------------
# Each formula is App. B with the COO word count 2 generalized to (1 + vw)
# and dense / value-only terms scaled by vw (see SparsityProfile.vw).

def dense_allreduce(p: SparsityProfile, n: int) -> float:
    """Ring allreduce: reduce-scatter + all-gather."""
    return 2 * (n - 1) / n * p.M * p.vw


def agsparse(p: SparsityProfile, n: int) -> float:
    """AllGather of COO sparse tensors (one-shot, centralization)."""
    return (1 + p.vw) * (n - 1) * p.d(1) * p.M


def sparcml(p: SparsityProfile, n: int) -> float:
    """SSAR_Recursive_double: log n stages of pairwise COO exchange with
    incremental aggregation; stage i exchanges density d(2^(i-1))."""
    stages = int(math.log2(n))
    return sum((1 + p.vw) * p.d(2 ** (i - 1)) * p.M
               for i in range(1, stages + 1))


def sparse_ps(p: SparsityProfile, n: int) -> float:
    """Even-range partitioning PS: skew-penalized push and pull (App. B.1):
    2 (n-1) s^n (d_G + d_G^n) M / n."""
    return (1 + p.vw) * (n - 1) * p.s(n) * (p.d(1) + p.d(n)) * p.M / n


def omnireduce(p: SparsityProfile, n: int) -> float:
    """Block-format PS. Non-zero blocks carry ``block`` values + 1 id word.
    The bottleneck aggregator receives the hottest partition's blocks from
    every worker (push) and broadcasts its aggregated blocks (pull)."""
    # wire words per gradient in a non-zero block
    w = (p.block * p.vw + 1) / p.block
    if p.block_max is not None:
        push = (n - 1) * p.block_max(1, n) * w * p.M / n
        pull = (n - 1) * p.block_max(n, n) * w * p.M / n
        return push + pull
    assert p.block_density is not None
    push = (n - 1) * p.s(n) * p.block_density(1) * w * p.M / n
    pull = (n - 1) * p.s(n) * p.block_density(n) * w * p.M / n
    return push + pull


def balanced_parallelism(p: SparsityProfile, n: int) -> float:
    """Theorem 1.2's optimal scheme with COO (skew = 1 by construction):
    2 (n-1)(d_G + d_G^n) M / n."""
    return (1 + p.vw) * (n - 1) * (p.d(1) + p.d(n)) * p.M / n


def zen(p: SparsityProfile, n: int) -> float:
    """Balanced Parallelism + hash bitmap on Pull (§3.2.2):
    push COO (low density), pull values + M/32-word bitmap (Thm. 3)."""
    push = (1 + p.vw) * (n - 1) * p.d(1) * p.M / n
    pull = (n - 1) / n * (p.d(n) * p.M * p.vw + p.M / 32)
    return push + pull


def lower_bound(p: SparsityProfile, n: int) -> float:
    """§4.1 footnote 3: receive the aggregated non-zeros of the other n-1
    workers, index-free: d_G^(n-1) M."""
    return p.d(n - 1) * p.M * p.vw if n > 1 else 0.0


SCHEMES: dict[str, Callable[[SparsityProfile, int], float]] = {
    "dense": dense_allreduce,
    "agsparse": agsparse,
    "sparcml": sparcml,
    "sparse_ps": sparse_ps,
    "omnireduce": omnireduce,
    "balanced_parallelism": balanced_parallelism,
    "zen": zen,
    "lower_bound": lower_bound,
}


def normalized_times(p: SparsityProfile, n: int) -> dict[str, float]:
    """All schemes normalized to dense ring-allreduce (Fig. 7 y-axis)."""
    base = dense_allreduce(p, n)
    return {name: fn(p, n) / base for name, fn in SCHEMES.items()}


# --- offline auto-scheme decision (runtime fallback, shared with Fig. 7) ----

def worst_case_profile(M: int, density: float, vw: int = 1) -> SparsityProfile:
    """Profile for a tensor whose per-step sparsity is only known by budget:
    no-overlap densification d(i) = min(i·d_G, 1) (the adversarial case for
    Zen's pull) and skew 1 (irrelevant to zen/dense)."""
    return SparsityProfile(
        M=M, d=lambda i: min(1.0, max(i, 1) * density), s=lambda n: 1.0, vw=vw)


def choose_scheme(
    p: SparsityProfile, n: int, *, threshold: float = 1.0
) -> str:
    """Per-tensor scheme choice from a (measured or worst-case) profile:
    'zen' iff its wire volume beats dense ring allreduce by ``threshold``.
    This is the decision the bucket planner applies tensor-by-tensor —
    scheme='auto' is per-leaf, never global (a high-density table falls
    back to dense without dragging genuinely sparse tables with it)."""
    if n < 2:
        return "dense"  # single worker: nothing to sync, dense psum is free
    return "zen" if zen(p, n) < threshold * dense_allreduce(p, n) else "dense"


def zen_beats_dense(
    rows: int, d: int, n: int, *, density_budget: float,
    threshold: float = 1.0,
) -> bool:
    """The 'auto' scheme's per-leaf offline choice: sync a [rows, d] row-sparse
    leaf with Zen iff its worst-case wire volume beats dense ring allreduce by
    ``threshold``.  Built from the same ``zen`` / ``dense_allreduce`` formulas
    as the Fig. 7 analytics so the runtime fallback cannot drift from them.
    """
    p = worst_case_profile(rows, density_budget, vw=max(d, 1))
    return choose_scheme(p, n, threshold=threshold) == "zen"

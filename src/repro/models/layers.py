"""Primitive layers with explicit tensor parallelism (Megatron-style).

All functions take the local parameter shard and a ``ShardCtx``; collectives
over the ``model`` axis are explicit (`psum` after row-parallel matmuls,
max/sum-reductions for vocab-sharded softmax).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models.common import (ParamBuilder, ShardCtx, zero_rows_from)

# ---------------------------------------------------------------------------
# Norms (replicated)
# ---------------------------------------------------------------------------

def init_rmsnorm(b: ParamBuilder, name: str, d: int):
    b.ones(name, (d,), P(None), dtype=jnp.float32)


def rmsnorm(scale, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def rmsnorm_sharded(scale_local, x_local, ctx: "ShardCtx", eps=1e-5):
    """RMSNorm over a model-sharded feature dim: exact full-dim variance via
    psum; ``scale_local`` is this rank's slice (spec P('model'))."""
    xf = x_local.astype(jnp.float32)
    full = x_local.shape[-1] * max(ctx.tp, 1)
    var = ctx.psum_tp(jnp.sum(xf * xf, axis=-1, keepdims=True)) / full
    return (xf * jax.lax.rsqrt(var + eps) * scale_local).astype(x_local.dtype)


# ---------------------------------------------------------------------------
# Column / row parallel linear
# ---------------------------------------------------------------------------

def init_linear(b: ParamBuilder, name: str, d_in: int, d_out: int, *,
                mode: str, tp: int, bias: bool = False, scale=None):
    """mode: 'col' shards d_out, 'row' shards d_in, 'rep' replicates."""
    if mode == "col":
        assert d_out % tp == 0, (name, d_out, tp)
        spec_w, spec_b = P(None, "model"), P("model")
    elif mode == "row":
        assert d_in % tp == 0, (name, d_in, tp)
        spec_w, spec_b = P("model", None), P(None)
    else:
        spec_w, spec_b = P(None, None), P(None)
    b.dense(f"{name}_w", (d_in, d_out), spec_w, scale=scale)
    if bias:
        b.zeros(f"{name}_b", (d_out,), spec_b)


def linear_col(p, name, x):
    """Column-parallel: out feature dim is sharded; no collective."""
    y = x @ p[f"{name}_w"]
    if f"{name}_b" in p:
        y = y + p[f"{name}_b"]
    return y


def linear_row(p, name, x, ctx: ShardCtx):
    """Row-parallel: contraction dim is sharded; psum over model."""
    y = ctx.psum_tp(x @ p[f"{name}_w"])
    if f"{name}_b" in p:
        y = y + p[f"{name}_b"]
    return y


def linear_rep(p, name, x):
    y = x @ p[f"{name}_w"]
    if f"{name}_b" in p:
        y = y + p[f"{name}_b"]
    return y


# ---------------------------------------------------------------------------
# Vocab-parallel embedding + LM head (+ sharded cross-entropy)
# ---------------------------------------------------------------------------

def init_embedding(b: ParamBuilder, name: str, vocab: int,
                   vocab_padded: int, d: int):
    """Embedding table, vocab-sharded over model.

    The table is the row-sparse gradient tensor Zen synchronizes — the leaf
    path must match ``GradSync.sparse_paths`` (we use '<name>/table').

    Padding rows [vocab:vocab_padded) are zero-initialized: padded ids are
    never produced by the pipeline, so a non-zero init there would be dead
    weight that (tied or head-side) could leak into the sharded logsumexp.
    Their gradient is identically zero, so they never show up as non-zero
    rows in the Zen encode or the measured d(1)/d(n) densities.
    """
    sub = b.child(name)
    sub.dense("table", (vocab_padded, d), P("model", None), scale=0.02)
    zero_rows_from(sub, "table", vocab)


def embed_lookup(p, name, tokens, ctx: ShardCtx):
    """tokens [B, S] -> [B, S, d]; table local shard is [Vp/tp, d]."""
    table = p[name]["table"]
    v_local = table.shape[0]
    off = ctx.tp_rank() * v_local if ctx.tp > 1 else 0
    local = tokens - off
    ok = (local >= 0) & (local < v_local)
    safe = jnp.clip(local, 0, v_local - 1)
    out = table[safe] * ok[..., None].astype(table.dtype)
    return ctx.psum_tp(out)


def mask_padded_logits(lf, ctx: ShardCtx, valid_vocab: int | None):
    """Set logits of padded vocab columns (global id >= ``valid_vocab``) to
    ``NEG`` so they vanish from logsumexp/argmax and carry zero gradient —
    padding must never change the loss, the sampled token, or the gradients
    feeding the sync path (DESIGN.md §9)."""
    if valid_vocab is None:
        return lf
    v_local = lf.shape[-1]
    off = ctx.tp_rank() * v_local if ctx.tp > 1 else 0
    ok = (off + jnp.arange(v_local)) < valid_vocab
    return jnp.where(ok, lf, jnp.asarray(NEG, lf.dtype))


def cross_entropy_parts(logits_l, labels, ctx: ShardCtx, mask=None, *,
                        valid_vocab: int | None = None):
    """(nll_sum, token_count) over vocab-sharded logits [.., V/tp].

    ``valid_vocab`` excludes padded vocab columns from the logsumexp (and
    from the gradient); labels must always be < valid_vocab."""
    lf = mask_padded_logits(logits_l.astype(jnp.float32), ctx, valid_vocab)
    v_local = lf.shape[-1]
    # stop_gradient: the max shift is purely for numerical stability, and
    # pmax has no differentiation rule (its "gradient" would cancel anyway).
    m = lax.stop_gradient(ctx.pmax_tp(jnp.max(lf, axis=-1)))
    se = ctx.psum_tp(jnp.sum(jnp.exp(lf - m[..., None]), axis=-1))
    lse = jnp.log(se) + m
    off = ctx.tp_rank() * v_local if ctx.tp > 1 else 0
    loc = labels - off
    ok = (loc >= 0) & (loc < v_local)
    safe = jnp.clip(loc, 0, v_local - 1)
    picked = jnp.take_along_axis(lf, safe[..., None], axis=-1)[..., 0]
    correct = ctx.psum_tp(picked * ok.astype(jnp.float32))
    nll = lse - correct
    mf = (jnp.ones_like(nll) if mask is None
          else mask.astype(jnp.float32))
    return jnp.sum(nll * mf), jnp.sum(mf)


def cross_entropy_sharded(logits_l, labels, ctx: ShardCtx, *, mask=None,
                          valid_vocab: int | None = None):
    """Mean next-token CE over vocab-sharded logits (see parts)."""
    s, c = cross_entropy_parts(logits_l, labels, ctx, mask,
                               valid_vocab=valid_vocab)
    return s / jnp.maximum(c, 1.0)


def lm_head_loss_chunked(p, name, x, labels, ctx: ShardCtx, *, mask=None,
                         valid_vocab: int | None = None, chunk: int = 512):
    """Fused LM-head + CE, scanned over sequence chunks.

    Never materializes the full [B, S, V/tp] logits — the peak transient is
    [B, chunk, V/tp] (recomputed in backward via remat).  This is the
    difference between fitting and OOM at 200k vocab x 4k seq.
    """
    B, S, d = x.shape
    c = min(chunk, S)
    nc = -(-S // c)
    pad = nc * c - S
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    mp = (jnp.pad(mask, ((0, 0), (0, pad)))
          if mask is not None else (lp >= 0))
    xc = xp.reshape(B, nc, c, d).swapaxes(0, 1)
    lc = lp.reshape(B, nc, c).swapaxes(0, 1)
    mc = mp.reshape(B, nc, c).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, inp):
        s_acc, n_acc = carry
        x_b, l_b, m_b = inp
        logits = linear_col(p, name, x_b)
        s, n = cross_entropy_parts(logits, l_b, ctx, m_b,
                                   valid_vocab=valid_vocab)
        return (s_acc + s, n_acc + n), None

    (s, n), _ = lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                         (xc, lc, mc))
    return s / jnp.maximum(n, 1.0)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x [..., S, H, hd] (hd even), positions [..., S] int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # [..., S, half]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Flash-style chunked attention (training / prefill)
# ---------------------------------------------------------------------------

NEG = -1e30


def _flash_inner(qf, kc, vc, pos_q, Sk, *, causal, window, chunk):
    """Online-softmax over KV chunks for one q-block.

    qf: [B, Tq, KV, g, hd] (pre-scaled f32); kc/vc: [nC, B, chunk, KV, hd*];
    pos_q: [Tq] (traced)."""
    B, Tq, KV, g, hd = qf.shape
    hd_v = vc.shape[-1]

    def step(carry, inp):
        m, l, o = carry
        ci, kb, vb = inp
        # named_scope marks the score/softmax chain: a fused attention
        # kernel (repro.kernels.flash) keeps every buffer in here in VMEM.
        # The dry-run's --fused-attn accounting excludes these from the HBM
        # term (hlo_cost exclude_bytes_re="flash_fusable").
        with jax.named_scope("flash_fusable"):
            pos_k = ci * chunk + jnp.arange(chunk)
            s = jnp.einsum("bqkgh,bckh->bqkgc", qf, kb.astype(jnp.float32))
            valid = pos_k[None, :] < Sk
            if causal:
                valid = valid & (pos_k[None, :] <= pos_q[:, None])
            if window > 0:
                valid = valid & (pos_k[None, :] > pos_q[:, None] - window)
            s = jnp.where(valid[None, :, None, None, :], s, NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            o_new = o * corr[..., None] + jnp.einsum(
                "bqkgc,bckh->bqkgh", p, vb.astype(jnp.float32))
        return (m_new, l_new, o_new), None

    m0 = jnp.full((B, Tq, KV, g), NEG, jnp.float32)
    l0 = jnp.zeros((B, Tq, KV, g), jnp.float32)
    o0 = jnp.zeros((B, Tq, KV, g, hd_v), jnp.float32)
    nC = kc.shape[0]
    (m, l, o), _ = lax.scan(step, (m0, l0, o0), (jnp.arange(nC), kc, vc))
    return o / jnp.maximum(l, 1e-30)[..., None]


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    chunk: int = 512, q_chunk: int = 1024, q_offset: int = 0):
    """Memory-efficient attention, tiled over BOTH q and kv blocks
    (lax.scan) — the f32 score transient is bounded by
    B * q_chunk * H * chunk * 4 bytes regardless of sequence length.

    q: [B, Sq, H, hd]; k: [B, Sk, KV, hd]; v: [B, Sk, KV, hd_v] with
    H % KV == 0 (GQA).  ``hd_v`` may differ from ``hd`` (MLA).
    ``window > 0`` restricts attention to the last ``window`` positions
    (sliding-window variant enabling long_500k on attention archs).
    Pure jnp — XLA fuses this well on TPU; the running-max/denominator
    recurrence is the standard online-softmax.
    """
    B, Sq, H, hd = q.shape
    hd_v = v.shape[-1]
    Sk, KV = k.shape[1], k.shape[2]
    g = H // KV
    scale = 1.0 / math.sqrt(hd)
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, KV, g, hd)

    nC = (Sk + chunk - 1) // chunk
    pad = nC * chunk - Sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = kp.reshape(B, nC, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(B, nC, chunk, KV, hd_v).transpose(1, 0, 2, 3, 4)

    if Sq <= q_chunk:
        o = _flash_inner(qf, kc, vc, q_offset + jnp.arange(Sq), Sk,
                         causal=causal, window=window, chunk=chunk)
        return o.reshape(B, Sq, H, hd_v).astype(q.dtype)

    nQ = (Sq + q_chunk - 1) // q_chunk
    qpad = nQ * q_chunk - Sq
    qp = jnp.pad(qf, ((0, 0), (0, qpad), (0, 0), (0, 0), (0, 0)))
    qb = qp.reshape(B, nQ, q_chunk, KV, g, hd).transpose(1, 0, 2, 3, 4, 5)

    def q_step(_, inp):
        qi, qblk = inp
        pos_q = qi * q_chunk + jnp.arange(q_chunk)
        o = _flash_inner(qblk, kc, vc, pos_q, Sk,
                         causal=causal, window=window, chunk=chunk)
        return None, o

    _, ob = lax.scan(q_step, None, (jnp.arange(nQ), qb))
    out = ob.transpose(1, 0, 2, 3, 4, 5).reshape(B, nQ * q_chunk, H, hd_v)
    return out[:, :Sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention over a sequence-sharded KV cache
# ---------------------------------------------------------------------------

def decode_attention(q, k_loc, v_loc, pos_loc, t, ctx: ShardCtx, *,
                     window: int = 0):
    """One-token attention against a model-axis sequence-sharded cache.

    q: [B, H, hd] (H = local heads if shard_heads else all heads)
    k_loc: [B, Sl, KV, hd]; v_loc: [B, Sl, KV, hd_v] — this rank's
    round-robin slice (hd_v may differ, MLA).
    pos_loc: [Sl] global positions (-1 = never written).
    t: current global position (attend to pos <= t, and > t - window —
    the current token is written to the cache before attending).

    Combines partial softmax stats across the model axis (pmax + psum) —
    the context-parallel decode described in DESIGN.md §5; head-count
    divisibility is irrelevant here.
    """
    B, H, hd = q.shape
    hd_v = v_loc.shape[-1]
    KV = k_loc.shape[2]
    g = H // KV
    scale = 1.0 / math.sqrt(hd)
    qf = (q.astype(jnp.float32) * scale).reshape(B, KV, g, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qf, k_loc.astype(jnp.float32))
    valid = (pos_loc >= 0) & (pos_loc <= t)
    if window > 0:
        valid = valid & (pos_loc > t - window)
    s = jnp.where(valid[None, None, None, :], s, NEG)
    m_l = jnp.max(s, axis=-1)
    m = ctx.pmax_tp(m_l)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[None, None, None, :], p, 0.0)
    l = ctx.psum_tp(jnp.sum(p, axis=-1))
    o = ctx.psum_tp(jnp.einsum("bkgs,bskh->bkgh", p,
                               v_loc.astype(jnp.float32)))
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, H, hd_v).astype(q.dtype)


def cache_write(k_loc, v_loc, pos_loc, k_new, v_new, t, ctx: ShardCtx, *,
                window: int = 0):
    """Round-robin write of one token's K/V into the rank owning position t.

    Slot layout: position t lives on rank ``t % tp`` at slot
    ``(t // tp) % Sl`` (ring when a sliding window bounds the cache).
    """
    tp = max(ctx.tp, 1)
    Sl = k_loc.shape[1]
    rank = ctx.tp_rank() if ctx.tp > 1 else 0
    mine = (t % tp) == rank
    slot = (t // tp) % Sl
    k_upd = lax.dynamic_update_slice(
        k_loc, k_new[:, None].astype(k_loc.dtype), (0, slot, 0, 0))
    v_upd = lax.dynamic_update_slice(
        v_loc, v_new[:, None].astype(v_loc.dtype), (0, slot, 0, 0))
    p_upd = lax.dynamic_update_slice(
        pos_loc, jnp.asarray(t, pos_loc.dtype)[None], (slot,))
    k_loc = jnp.where(mine, k_upd, k_loc)
    v_loc = jnp.where(mine, v_upd, v_loc)
    pos_loc = jnp.where(mine, p_upd, pos_loc)
    return k_loc, v_loc, pos_loc


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU), column -> row parallel
# ---------------------------------------------------------------------------

def init_swiglu(b: ParamBuilder, name: str, d: int, d_ff: int, tp: int):
    sub = b.child(name)
    init_linear(sub, "gate", d, d_ff, mode="col", tp=tp)
    init_linear(sub, "up", d, d_ff, mode="col", tp=tp)
    init_linear(sub, "down", d_ff, d, mode="row", tp=tp)


def swiglu(p, name, x, ctx: ShardCtx):
    sub = p[name]
    h = jax.nn.silu(linear_col(sub, "gate", x)) * linear_col(sub, "up", x)
    return linear_row(sub, "down", h, ctx)


def init_gelu_mlp(b: ParamBuilder, name: str, d: int, d_ff: int, tp: int):
    sub = b.child(name)
    init_linear(sub, "up", d, d_ff, mode="col", tp=tp, bias=True)
    init_linear(sub, "down", d_ff, d, mode="row", tp=tp, bias=True)


def gelu_mlp(p, name, x, ctx: ShardCtx):
    sub = p[name]
    return linear_row(sub, "down", jax.nn.gelu(linear_col(sub, "up", x)), ctx)

"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

Training uses the chunked block decomposition: within-chunk quadratic
(attention-like) term + inter-chunk recurrent state pass via ``lax.scan``.
Decode is the O(1) recurrence on the [B, H, hd, dstate] state.

Tensor parallelism: SSM heads (d_inner / head_dim) are column-parallel over
``model`` (always divisible in the assigned zoo); B/C projections are
replicated (they are shared across heads, ngroups=1); out-proj is
row-parallel.  The scan itself is purely local — the paper's technique does
not apply to the recurrence (DESIGN.md §4) and gradients of SSM parameters
are dense.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models.common import ArchConfig, ParamBuilder, ShardCtx
from repro.models import layers as L


def _heads_local(cfg: ArchConfig, ctx: ShardCtx) -> int:
    assert cfg.ssm_heads % ctx.tp == 0, (cfg.ssm_heads, ctx.tp)
    return cfg.ssm_heads // ctx.tp


def init_mamba2(b: ParamBuilder, name: str, cfg: ArchConfig, ctx: ShardCtx):
    sub = b.child(name)
    d, din, hs = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H = cfg.ssm_heads
    # z (gate) and x: SEPARATE column-parallel projections — packing them
    # into one matrix would interleave z/x columns across TP ranks
    L.init_linear(sub, "in_z", d, din, mode="col", tp=ctx.tp)
    L.init_linear(sub, "in_x", d, din, mode="col", tp=ctx.tp)
    L.init_linear(sub, "in_dt", d, H, mode="col", tp=ctx.tp)
    L.init_linear(sub, "in_bc", d, 2 * hs, mode="rep", tp=ctx.tp)  # shared B, C
    L.init_linear(sub, "out", din, d, mode="row", tp=ctx.tp)
    sub.dense("conv_w", (cfg.ssm_conv, din), P(None, "model"), scale=0.5)
    sub.zeros("conv_b", (din,), P("model"))
    sub.const("A_log", jnp.zeros((H,), jnp.float32), P("model"))
    sub.zeros("dt_bias", (H,), P("model"), dtype=jnp.float32)
    sub.zeros("D", (H,), P("model"), dtype=jnp.float32)
    # gated norm over the sharded d_inner dim: scale is model-sharded and
    # the variance is psum'd (layers.rmsnorm_sharded)
    sub.ones("norm", (din,), P("model"), dtype=jnp.float32)
    # Per-head vectors keep P("model") at every tp (a 1-sized model axis
    # shards trivially): the spec TREE is identical on every mesh, which
    # the §9 contract relies on — only axis sizes may differ.


def _causal_conv(x, w, bias):
    """Depthwise causal conv1d. x [B, S, C], w [K, C]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(xp[:, k:k + x.shape[1], :] * w[k] for k in range(K))
    return y + bias


def _ssd_chunked(xh, dt, a_log, B, C, D, chunk: int):
    """Chunked SSD scan.

    xh: [Bt, S, H, hd]; dt: [Bt, S, H] (post-softplus); a_log: [H] (A = -exp);
    B, C: [Bt, S, N]; D: [H].  Returns y [Bt, S, H, hd] and final state
    [Bt, H, hd, N].
    """
    Bt, S, H, hd = xh.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        # zero-padded steps are identity: dt=0 => decay=1, input=0
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    S_p = S + pad
    nC = S_p // Q
    A = -jnp.exp(a_log)                                   # [H] negative
    dA = dt * A[None, None, :]                            # [Bt, S, H] log-decay
    xdt = xh * dt[..., None]                              # input scaled by dt

    # reshape into chunks
    def ch(t):
        return t.reshape(Bt, nC, Q, *t.shape[2:]).swapaxes(0, 1)
    dA_c, x_c, B_c, C_c = ch(dA), ch(xdt), ch(B), ch(C)   # leading nC

    def chunk_step(state, inp):
        dA_q, x_q, B_q, C_q = inp                          # [Bt,Q,H,..]
        cs = jnp.cumsum(dA_q, axis=1)                      # [Bt,Q,H]
        total = cs[:, -1]                                  # [Bt,H]
        # intra-chunk (lower-triangular decay kernel)
        Lmat = cs[:, :, None, :] - cs[:, None, :, :]       # [Bt,Qi,Qj,H]
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        decay = jnp.where(tri[None, :, :, None], jnp.exp(Lmat), 0.0)
        sBC = jnp.einsum("bin,bjn->bij", C_q, B_q)         # [Bt,Qi,Qj]
        y_in = jnp.einsum("bij,bijh,bjhd->bihd", sBC, decay, x_q)
        # inter-chunk: contribution of carried state
        y_st = jnp.einsum("bin,bhdn,bih->bihd", C_q, state, jnp.exp(cs))
        # state update
        w = jnp.exp(total[:, None, :] - cs)                # [Bt,Q,H]
        dS = jnp.einsum("bqhd,bqn,bqh->bhdn", x_q, B_q, w)
        state = state * jnp.exp(total)[:, :, None, None] + dS
        return state, y_in + y_st

    s0 = jnp.zeros((Bt, H, hd, N), jnp.float32)
    state, y = lax.scan(chunk_step, s0, (dA_c, x_c, B_c, C_c))
    y = y.swapaxes(0, 1).reshape(Bt, S_p, H, hd)[:, :S]
    return y + xh[:, :S] * D[None, None, :, None], state


def mamba2_train(p, name, x, cfg: ArchConfig, ctx: ShardCtx,
                 return_cache: bool = False):
    """Full-sequence Mamba2 block. x [B, S, d] -> [B, S, d].

    ``return_cache=True`` also returns the decode cache (final SSD state +
    conv tail) so prefill hands off to recurrent decode exactly."""
    sub = p[name]
    Bt, S, _ = x.shape
    Hl = _heads_local(cfg, ctx)
    hd, N = cfg.ssm_head_dim, cfg.ssm_state
    z = L.linear_col(sub, "in_z", x)
    xs_raw = L.linear_col(sub, "in_x", x)
    dinl = xs_raw.shape[-1]
    xs = jax.nn.silu(_causal_conv(xs_raw, sub["conv_w"], sub["conv_b"]))
    dt = jax.nn.softplus(
        L.linear_col(sub, "in_dt", x).astype(jnp.float32)
        + sub["dt_bias"][None, None])
    bc = L.linear_rep(sub, "in_bc", x).astype(jnp.float32)
    Bm, Cm = bc[..., :N], bc[..., N:]
    xh = xs.reshape(Bt, S, Hl, hd).astype(jnp.float32)
    y, state = _ssd_chunked(xh, dt, sub["A_log"], Bm, Cm, sub["D"],
                            cfg.ssm_chunk)
    y = y.reshape(Bt, S, dinl).astype(x.dtype)
    y = L.rmsnorm_sharded(sub["norm"], y * jax.nn.silu(z), ctx)
    out = L.linear_row(sub, "out", y, ctx)
    if return_cache:
        K = cfg.ssm_conv
        cache = {"state": state, "conv": xs_raw[:, S - (K - 1):, :]}
        return out, cache
    return out


def mamba2_make_cache(cfg: ArchConfig, ctx: ShardCtx, batch: int,
                      dtype=jnp.float32):
    Hl = cfg.ssm_heads // ctx.tp
    return {
        "state": jnp.zeros((batch, Hl, cfg.ssm_head_dim, cfg.ssm_state),
                           jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1,
                           cfg.d_inner // ctx.tp), dtype),
    }


def mamba2_decode(p, name, x, cache, cfg: ArchConfig, ctx: ShardCtx):
    """One-token recurrence. x [B, d]. O(1) in sequence length — this is why
    mamba2/zamba2 run long_500k natively."""
    sub = p[name]
    Bt = x.shape[0]
    Hl = _heads_local(cfg, ctx)
    hd, N = cfg.ssm_head_dim, cfg.ssm_state
    z = L.linear_col(sub, "in_z", x)
    xs = L.linear_col(sub, "in_x", x)
    dinl = xs.shape[-1]
    # conv cache: [B, K-1, dinl]
    conv_in = jnp.concatenate([cache["conv"], xs[:, None, :]], axis=1)
    w = sub["conv_w"]
    y_conv = jnp.einsum("bkc,kc->bc", conv_in, w) + sub["conv_b"]
    xs = jax.nn.silu(y_conv)
    new_conv = conv_in[:, 1:]
    dt = jax.nn.softplus(
        L.linear_col(sub, "in_dt", x).astype(jnp.float32)
        + sub["dt_bias"][None])                            # [B, Hl]
    bc = L.linear_rep(sub, "in_bc", x).astype(jnp.float32)
    Bm, Cm = bc[..., :N], bc[..., N:]
    A = -jnp.exp(sub["A_log"])
    xh = xs.reshape(Bt, Hl, hd).astype(jnp.float32)
    decay = jnp.exp(dt * A[None])                          # [B, Hl]
    state = (cache["state"] * decay[..., None, None]
             + jnp.einsum("bhd,bn,bh->bhdn", xh, Bm, dt))
    y = jnp.einsum("bn,bhdn->bhd", Cm, state)
    y = y + xh * sub["D"][None, :, None]
    y = y.reshape(Bt, dinl).astype(x.dtype)
    y = L.rmsnorm_sharded(sub["norm"], y * jax.nn.silu(z), ctx)
    out = L.linear_row(sub, "out", y, ctx)
    return out, {"state": state, "conv": new_conv}

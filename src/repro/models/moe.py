"""Mixture-of-Experts FFN with expert parallelism over the ``model`` axis.

Activations are replicated across ``model`` (they are sharded over ``data``
only), so expert parallelism needs no token all-to-all: each model-rank
gathers the tokens routed to its local experts, runs the expert FFNs, and the
partial outputs are combined by the row-parallel psum that follows.  Capacity
is fixed (static shapes): ``cap = ceil(T * top_k / E * capacity_factor)``;
dropped-token and load-balance statistics are returned for the router-skew
analysis (the MoE analogue of the paper's Def. 5 skew).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models.common import ArchConfig, ParamBuilder, ShardCtx


def init_moe(b: ParamBuilder, name: str, cfg: ArchConfig, ctx: ShardCtx):
    sub = b.child(name)
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    assert E % ctx.tp == 0, (E, ctx.tp)
    sub.dense("router_w", (d, E), P(None, None), scale=0.02)
    # expert weights: [E, ...] sharded over model on the expert dim
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(f)
    sub.dense("w_gate", (E, d, f), P("model", None, None), scale=scale_in)
    sub.dense("w_up", (E, d, f), P("model", None, None), scale=scale_in)
    sub.dense("w_down", (E, f, d), P("model", None, None), scale=scale_out)


def moe_ffn(p, name, x, cfg: ArchConfig, ctx: ShardCtx):
    """Dispatch-strategy switch: baseline replicated-token dispatch, or the
    §Perf token-sharded all-to-all dispatch (``ctx.moe_a2a``)."""
    tokens = x.shape[0] * x.shape[1]
    if (getattr(ctx, "moe_a2a", False) and ctx.tp > 1
            and tokens % ctx.tp == 0):
        return moe_ffn_a2a(p, name, x, cfg, ctx)
    # decode steps (T < tp) and non-divisible token counts use the
    # replicated dispatch
    return moe_ffn_replicated(p, name, x, cfg, ctx)


def moe_ffn_replicated(p, name, x, cfg: ArchConfig, ctx: ShardCtx):
    """x [B, S, d] -> (y [B, S, d], stats dict)."""
    sub = p[name]
    Bt, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    El = E // ctx.tp
    T = Bt * S
    xf = x.reshape(T, d)

    # ---- routing (replicated) ----------------------------------------------
    logits = (xf @ sub["router_w"].astype(xf.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)                 # [T, K]
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)  # renormalize top-k

    # ---- dispatch: rank within each expert's queue ---------------------------
    cap = max(1, int(math.ceil(T * K / E * cfg.capacity_factor)))
    flat_e = eidx.reshape(T * K)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    pos_in_e = jnp.arange(T * K) - jnp.searchsorted(sorted_e, sorted_e,
                                                    side="left")
    rank_in_e = jnp.zeros(T * K, jnp.int32).at[order].set(
        pos_in_e.astype(jnp.int32))
    keep = rank_in_e < cap
    slot = flat_e * cap + rank_in_e                      # [T*K] in [0, E*cap)

    # scatter token ids into the global dispatch buffer, slice local experts
    tok_of = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    buf_tok = jnp.full((E * cap,), T, jnp.int32)         # T = sentinel
    buf_tok = buf_tok.at[jnp.where(keep, slot, E * cap)].set(
        tok_of, mode="drop")
    buf_gate = jnp.zeros((E * cap,), jnp.float32).at[
        jnp.where(keep, slot, E * cap)].set(gate.reshape(-1), mode="drop")
    r = ctx.tp_rank() if ctx.tp > 1 else 0
    loc_tok = jax.lax.dynamic_slice(buf_tok, (r * El * cap,),
                                    (El * cap,)).reshape(El, cap)
    loc_gate = jax.lax.dynamic_slice(buf_gate, (r * El * cap,),
                                     (El * cap,)).reshape(El, cap)

    # ---- expert FFN (vmapped over local experts) -----------------------------
    safe_tok = jnp.where(loc_tok == T, 0, loc_tok)
    xin = xf[safe_tok]                                   # [El, cap, d]
    xin = jnp.where((loc_tok == T)[..., None], 0, xin)

    def expert(wg, wu, wd, xi):
        h = jax.nn.silu(xi @ wg) * (xi @ wu)
        return h @ wd

    yex = jax.vmap(expert)(sub["w_gate"], sub["w_up"], sub["w_down"], xin)
    yex = yex * loc_gate[..., None].astype(yex.dtype)

    # ---- combine: scatter-add back, psum over model --------------------------
    out = jnp.zeros((T, d), yex.dtype)
    out = out.at[loc_tok.reshape(-1)].add(yex.reshape(-1, d), mode="drop")
    out = ctx.psum_tp(out)

    # ---- stats ----------------------------------------------------------------
    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    # (scatter-add histogram, not one_hot — avoids a [T, K, E] transient)
    f_e = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(1.0) / T  # [E]
    p_e = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f_e / K * p_e)
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    # router skew ≙ Def. 5: max expert load / mean load
    skew = jnp.max(f_e) / jnp.maximum(jnp.mean(f_e), 1e-9)
    stats = {"moe/aux_loss": aux, "moe/dropped": dropped, "moe/skew": skew}
    return out.reshape(Bt, S, d).astype(x.dtype), stats


def moe_ffn_a2a(p, name, x, cfg: ArchConfig, ctx: ShardCtx):
    """§Perf token-sharded expert dispatch (beyond-paper optimization).

    Baseline replicates routing+dispatch over the model axis and combines
    expert outputs with a full-activation psum (2(g-1)/g * T*d on the wire
    per layer).  Here each model rank routes only its T/tp token slice and
    ships tokens to expert owners with two all-to-alls, then all-gathers
    the sharded output: wire ~ (2*K*cf/tp + 1) * (g-1)/g * T*d — ~35% less
    at phi3.5's K=2, and 16x less routing/dispatch compute and buffers.
    Equivalent to the baseline up to capacity-drop boundaries
    (per-slice instead of global capacity).
    """
    sub = p[name]
    Bt, S, d = x.shape
    E, K, tp = cfg.n_experts, cfg.top_k, ctx.tp
    El = E // tp
    T = Bt * S
    assert T % tp == 0
    Tl = T // tp
    r = ctx.tp_rank()
    xf = x.reshape(T, d)
    xl = jax.lax.dynamic_slice(xf, (r * Tl, jnp.int32(0)), (Tl, d))

    # ---- local routing on the token slice -----------------------------------
    logits = (xl @ sub["router_w"].astype(xl.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)                  # [Tl, K]
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # ---- local dispatch into per-(expert) queues -----------------------------
    cap = max(1, int(math.ceil(Tl * K / E * cfg.capacity_factor)))
    flat_e = eidx.reshape(Tl * K)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    pos_in_e = jnp.arange(Tl * K) - jnp.searchsorted(sorted_e, sorted_e,
                                                     side="left")
    rank_in_e = jnp.zeros(Tl * K, jnp.int32).at[order].set(
        pos_in_e.astype(jnp.int32))
    keep = rank_in_e < cap
    slot = flat_e * cap + rank_in_e
    tok_of = jnp.repeat(jnp.arange(Tl, dtype=jnp.int32), K)
    buf_tok = jnp.full((E * cap,), Tl, jnp.int32).at[
        jnp.where(keep, slot, E * cap)].set(tok_of, mode="drop")
    buf_gate = jnp.zeros((E * cap,), jnp.float32).at[
        jnp.where(keep, slot, E * cap)].set(gate.reshape(-1), mode="drop")
    safe_tok = jnp.where(buf_tok == Tl, 0, buf_tok)
    xin = xl[safe_tok]
    xin = jnp.where((buf_tok == Tl)[:, None], 0, xin)     # [E*cap, d]

    # ---- ship tokens to expert owners (all-to-all over model) ---------------
    send = xin.reshape(tp, El * cap, d)
    recv = lax.all_to_all(send, ctx.tp_axis, split_axis=0, concat_axis=0)
    g_send = buf_gate.reshape(tp, El * cap)
    g_recv = lax.all_to_all(g_send, ctx.tp_axis, split_axis=0, concat_axis=0)

    # ---- expert FFN on my El experts, tokens from every source rank ---------
    xin_e = recv.reshape(tp, El, cap, d).transpose(1, 0, 2, 3) \
                .reshape(El, tp * cap, d)

    def expert(wg, wu, wd, xi):
        h = jax.nn.silu(xi @ wg) * (xi @ wu)
        return h @ wd

    yex = jax.vmap(expert)(sub["w_gate"], sub["w_up"], sub["w_down"], xin_e)
    g_e = g_recv.reshape(tp, El, cap).transpose(1, 0, 2).reshape(El, tp * cap)
    yex = yex * g_e[..., None].astype(yex.dtype)

    # ---- ship results back, combine into the local token slice ---------------
    back = yex.reshape(El, tp, cap, d).transpose(1, 0, 2, 3) \
              .reshape(tp, El * cap, d)
    got = lax.all_to_all(back, ctx.tp_axis, split_axis=0, concat_axis=0)
    out_l = jnp.zeros((Tl, d), got.dtype)
    out_l = out_l.at[buf_tok].add(got.reshape(E * cap, d), mode="drop")

    # ---- restore replication --------------------------------------------------
    out = lax.all_gather(out_l, ctx.tp_axis, tiled=True)  # [T, d]

    f_e = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(1.0) / Tl
    f_e = lax.pmean(f_e, ctx.tp_axis)
    p_e = lax.pmean(jnp.mean(probs, axis=0), ctx.tp_axis)
    aux = E * jnp.sum(f_e / K * p_e)
    dropped = lax.pmean(1.0 - jnp.mean(keep.astype(jnp.float32)),
                        ctx.tp_axis)
    skew = jnp.max(f_e) / jnp.maximum(jnp.mean(f_e), 1e-9)
    stats = {"moe/aux_loss": aux, "moe/dropped": dropped, "moe/skew": skew}
    return out.reshape(Bt, S, d).astype(x.dtype), stats

"""Model-zoo foundations: architecture config, shard context, param utilities.

Everything model-side runs *inside* ``shard_map`` (Megatron-style explicit
tensor parallelism) so gradient synchronization — the paper's subject — is an
explicit, schedulable operation rather than a compiler insertion.  Parameters
are global arrays with a mirrored ``PartitionSpec`` tree; inside the shard_map
region each leaf is its local shard.

Sharding rules (DESIGN.md §5):
  * MLP / expert / SSM inner dims: column→row parallel over ``model``
    (always divisible for the assigned zoo).
  * Attention q-heads: sharded over ``model`` iff ``n_heads % tp == 0``,
    else replicated (qwen2-0.5b 14H, phi4-mini 24H, minicpm3 40H).
  * KV projections: always replicated (kv-head counts are small and rarely
    divide tp; the FLOP share is negligible).
  * Embedding / LM head: vocab-sharded over ``model`` (vocab padded to a
    multiple of 128 — standard practice; padded ids are never produced).
  * Decode KV cache: sequence-sharded over ``model`` (round-robin slots) —
    works for any head count and divides cache HBM by tp.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

# Vocab is always padded to a multiple of this, independent of the mesh
# (DESIGN.md §9): parameter SHAPES must never depend on tp.  make_ctx
# asserts vocab_padded % tp == 0 instead of growing the pad.
VOCAB_PAD = 128


def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture (exact sizes from the assignment block)."""

    name: str
    kind: str                  # dense | moe | ssm | hybrid | enc_dec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0          # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / hybrid) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 64
    # --- hybrid (zamba2-style shared attention block) ---
    shared_attn_every: int = 0     # apply shared attn block every k ssm layers
    # --- MLA (minicpm3) ---
    mla_q_rank: int = 0            # 0 -> standard GQA
    mla_kv_rank: int = 0
    mla_rope_dim: int = 32
    mla_v_dim: int = 64
    # --- enc-dec (whisper backbone) ---
    n_enc_layers: int = 0
    enc_len: int = 1500            # encoder frames (stub embeddings)
    # --- vlm ---
    n_patches: int = 0             # patch-embedding prefix length (stub)
    # --- long-context ---
    sliding_window: int = 4096     # used by long_500k decode for attn archs
    # --- numerics ---
    dtype: Any = jnp.bfloat16
    source: str = ""               # citation

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_attn_free(self) -> bool:
        return self.kind == "ssm"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a fixed multiple of ``VOCAB_PAD`` — deliberately
        NOT a function of the mesh, so the embedding / LM-head shapes (and
        therefore the init key→param mapping) are identical on every mesh.
        ``make_ctx`` asserts divisibility by tp instead."""
        return pad_to(self.vocab, VOCAB_PAD)

    def n_params(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d
        if self.kind == "ssm":
            din = self.d_inner
            per = (d * (2 * din + 2 * self.ssm_heads + 2 * self.ssm_state)
                   + din * d + din * self.ssm_conv)
            return emb + L * per
        attn = d * (self.n_heads * self.hd) * 2 + d * (self.n_kv * self.hd) * 2
        if self.mla_q_rank:
            attn = (d * self.mla_q_rank
                    + self.mla_q_rank * self.n_heads * (self.hd + self.mla_rope_dim)
                    + d * (self.mla_kv_rank + self.mla_rope_dim)
                    + self.mla_kv_rank * self.n_heads * (self.hd + self.mla_v_dim)
                    + self.n_heads * self.mla_v_dim * d)
        if self.kind == "moe":
            ffn = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
        else:
            ffn = 3 * d * self.d_ff
        per = attn + ffn
        total = emb + L * per
        if self.kind == "enc_dec":
            total += self.n_enc_layers * (attn + ffn) + L * attn  # cross-attn
        if self.kind == "hybrid":
            din = self.d_inner
            ssm_per = (d * (2 * din + 2 * self.ssm_heads + 2 * self.ssm_state)
                       + din * d + din * self.ssm_conv)
            total = emb + L * ssm_per + (attn + ffn)  # one shared block
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE uses top_k of n_experts)."""
        if self.kind != "moe":
            return self.n_params()
        d, L = self.d_model, self.n_layers
        attn = d * (self.n_heads * self.hd) * 2 + d * (self.n_kv * self.hd) * 2
        ffn = self.top_k * 3 * d * self.d_ff + d * self.n_experts
        return self.vocab * d + L * (attn + ffn)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: 2 layers, d_model<=256, <=4 experts."""
        return dataclasses.replace(
            self,
            n_layers=2,
            n_enc_layers=min(self.n_enc_layers, 2),
            d_model=256,
            n_heads=4,
            n_kv=min(self.n_kv, 2) if self.n_kv < self.n_heads else 4,
            head_dim=64,
            d_ff=384,
            vocab=512,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            mla_q_rank=min(self.mla_q_rank, 64),
            mla_kv_rank=min(self.mla_kv_rank, 32),
            enc_len=min(self.enc_len, 24),
            n_patches=min(self.n_patches, 8),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_chunk=16,
            sliding_window=128,
            shared_attn_every=min(self.shared_attn_every, 1) or self.shared_attn_every,
        )


# ---------------------------------------------------------------------------
# Shard context
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Mesh axes + per-family sharding decisions, fixed at build time.

    Mesh-invariance contract (DESIGN.md §9): every layer computes *global*
    semantics — the mesh only chooses the layout.  Concretely:

      * global parameter shapes, dtypes, and pytree paths are identical for
        every (tp, dp, pods) — only the ``PartitionSpec`` trees differ
        (``assert_mesh_invariant_params`` enforces this on every build);
      * each leaf's init key is a pure function of (root key, leaf path)
        — see ``ParamBuilder`` — and random bits are sharding-invariant
        (``jax_threefry_partitionable``, enabled in ``repro/__init__``),
        so ``same key -> bitwise-same global param pytree`` on any mesh;
      * forward math is the same global function on every mesh: sharded
        reductions (psum / pmax over ``model``) reconstruct exactly the
        full-dim quantity, never a per-shard approximation;
      * divisibility preconditions are validated eagerly by ``make_ctx``
        with errors naming the config, never absorbed by growing shapes.

    The one documented exception is ``h_pad`` (opt-in ``pad_heads=True``):
    padding q-heads up to a tp multiple changes global shapes by design,
    trading bit-parity across meshes for shardability.
    """

    tp: int                        # model-axis size
    dp: int                        # data-axis size
    pods: int = 1
    tp_axis: str = "model"
    dp_axis: str = "data"
    pod_axis: Optional[str] = None
    # Hierarchical data parallelism (DESIGN.md §10): devices per node.
    # node_size > 1 splits the data axis into nested mesh axes
    # ("dp_inter", "dp_intra") — see ``dp_axes`` — so the sync stack can
    # aggregate within a node before crossing the slow inter-node links.
    # node_size == 1 keeps the single historical "data" axis: every
    # consumer sees exactly the pre-topology axis names and sizes.
    node_size: int = 1
    shard_heads: bool = True       # q-heads over tp (set from cfg)
    decode_seq_shard: bool = True  # KV cache sequence-sharded over tp
    # §Perf optimization: pad q-heads up to a tp multiple so attention can
    # shard instead of replicating (qwen2 14->16, phi4 24->32, minicpm3
    # 40->48).  Padded heads are zero-initialized: the function at init is
    # exactly the spec architecture; under training they become (tiny)
    # extra capacity — the standard Megatron-style padding trade-off.
    h_pad: int = 0                 # 0 = no padding; else the padded H
    # §Perf optimization: token-sharded MoE dispatch over the model axis
    # (two all-to-alls instead of a full-activation psum) — see moe.py
    moe_a2a: bool = False

    @property
    def dp_axes(self) -> tuple:
        """Mesh axes spanning the data-parallel world, outermost first:
        the single ``dp_axis`` when flat, ``(dp_inter, dp_intra)`` when
        node-split.  Collectives that must cover ALL data ranks (ZeRO
        gathers, metric pmeans) take this tuple as their axis name."""
        if self.node_size > 1:
            from repro.core.topology import DP_INTER, DP_INTRA
            return (DP_INTER, DP_INTRA)
        return (self.dp_axis,)

    @property
    def batch_axes(self):
        head = (self.pod_axis,) if self.pod_axis else ()
        return head + self.dp_axes

    @property
    def axis_sizes(self) -> dict:
        """{mesh axis name: size} for every axis this ctx shards over —
        the one table spec-divisor math should consult (steps.py)."""
        sizes = {self.tp_axis: self.tp}
        if self.node_size > 1:
            from repro.core.topology import DP_INTER, DP_INTRA
            sizes[DP_INTER] = self.dp // self.node_size
            sizes[DP_INTRA] = self.node_size
        else:
            sizes[self.dp_axis] = self.dp
        if self.pod_axis:
            sizes[self.pod_axis] = self.pods
        return sizes

    def tp_rank(self):
        return lax.axis_index(self.tp_axis)

    def psum_tp(self, x):
        return lax.psum(x, self.tp_axis) if self.tp > 1 else x

    def pmax_tp(self, x):
        """Cross-rank max, treated as a constant under differentiation
        (used only for numerical-stability shifts; ``pmax`` has no JVP rule).
        """
        if self.tp == 1:
            return x

        @jax.custom_jvp
        def f(y):
            return lax.pmax(y, self.tp_axis)

        @f.defjvp
        def _jvp(primals, tangents):
            (y,) = primals
            return f(y), jnp.zeros_like(y)

        return f(x)


def _require(cond: bool, cfg: ArchConfig, why: str) -> None:
    if not cond:
        raise ValueError(f"config '{cfg.name}': {why}")


def validate_tp(cfg: ArchConfig, tp: int, *, shard_heads: bool,
                h_pad: int) -> None:
    """Eager divisibility checks for a tensor-parallel degree.

    Shapes are mesh-invariant by contract (DESIGN.md §9), so a tp that does
    not divide them is a configuration error — reported here, at
    ``make_ctx`` time, with the config named, instead of surfacing as a
    bare assert deep inside a layer init."""
    if tp <= 1:
        return
    vp = cfg.vocab_padded
    _require(vp % tp == 0, cfg,
             f"padded vocab {vp} (vocab {cfg.vocab} padded to a fixed "
             f"multiple of {VOCAB_PAD}, mesh-invariant) is not divisible "
             f"by tp={tp}; pick a tp dividing {vp}")
    uses_mlp = (cfg.kind in ("dense", "enc_dec", "vlm", "hybrid")
                or bool(cfg.mla_q_rank))
    if uses_mlp:
        _require(cfg.d_ff % tp == 0, cfg,
                 f"d_ff={cfg.d_ff} is not divisible by tp={tp} "
                 f"(MLP is column->row parallel over the model axis)")
    if cfg.kind == "moe":
        _require(cfg.n_experts % tp == 0, cfg,
                 f"n_experts={cfg.n_experts} is not divisible by tp={tp} "
                 f"(experts are sharded over the model axis)")
    if cfg.kind in ("ssm", "hybrid"):
        _require(cfg.d_inner % tp == 0, cfg,
                 f"d_inner={cfg.d_inner} is not divisible by tp={tp}")
        _require(cfg.ssm_heads % tp == 0, cfg,
                 f"ssm_heads={cfg.ssm_heads} is not divisible by tp={tp}")
    # GQA head/KV nesting (MLA broadcasts k_rope per-head instead of
    # slicing replicated KV heads, so the nesting constraint is GQA-only)
    if (shard_heads and cfg.n_heads and not cfg.is_attn_free
            and not cfg.mla_q_rank):
        H = h_pad or cfg.n_heads
        _require(H % cfg.n_kv == 0, cfg,
                 f"n_heads={H} is not a multiple of n_kv={cfg.n_kv}")
        Hl, g = H // tp, H // cfg.n_kv
        _require(Hl % g == 0 or g % Hl == 0, cfg,
                 f"local q-heads {Hl} and GQA group {g} do not nest at "
                 f"tp={tp} (need Hl % g == 0 or g % Hl == 0 for the "
                 f"replicated-KV slice)")


def make_ctx(cfg: ArchConfig, tp: int, dp: int, pods: int = 1,
             pad_heads: bool = False, moe_a2a: bool = False,
             node_size: int = 1) -> ShardCtx:
    h_pad = 0
    shard = cfg.n_heads % tp == 0
    if pad_heads and not shard and cfg.n_heads > 0:
        h_pad = pad_to(cfg.n_heads, tp)
        shard = True
    validate_tp(cfg, tp, shard_heads=shard, h_pad=h_pad)
    if node_size > 1:
        _require(dp % node_size == 0, cfg,
                 f"node_size={node_size} does not divide the data-parallel "
                 f"degree dp={dp}; pick a node size dividing {dp} (or 1 "
                 f"for the flat topology)")
    return ShardCtx(
        tp=tp, dp=dp, pods=pods,
        pod_axis="pod" if pods > 1 else None,
        shard_heads=shard,
        h_pad=h_pad,
        moe_a2a=moe_a2a,
        node_size=max(node_size, 1),
    )


# ---------------------------------------------------------------------------
# Parameter initialization helpers (global arrays + mirrored PartitionSpecs)
# ---------------------------------------------------------------------------

def path_key(key: jax.Array, token: str | int) -> jax.Array:
    """Derive a child PRNG key from one path component.

    The key of every parameter leaf is a pure function of (root key, leaf
    path) — NOT of the order or number of sibling ``dense``/``child`` calls
    — so key assignment is provably independent of the mesh and of any
    layout decision an init function makes (DESIGN.md §9).  String
    components are folded in via a stable 31-bit CRC; integer components
    (stacked-layer indices) fold in directly and cannot collide with
    strings in practice because stacked layers live in their own
    name-derived subtree.
    """
    if isinstance(token, int):
        return jax.random.fold_in(key, token)
    return jax.random.fold_in(key, zlib.crc32(token.encode()) & 0x7FFFFFFF)


class ParamBuilder:
    """Collects (value, spec) pairs into mirrored pytrees.

    ``abstract=True`` records ``jax.ShapeDtypeStruct`` leaves instead of
    materializing arrays — used by the dry-run and by spec-tree construction
    (no allocation, no RNG).

    Key discipline: each leaf draws from ``path_key(subtree_key, name)``.
    There is no sequential key consumption, so two builds of the same
    architecture assign identical keys to identical paths no matter what
    mesh (or code path ordering) produced them.
    """

    def __init__(self, key: jax.Array | None, dtype=jnp.bfloat16,
                 abstract: bool = False):
        self._key = key
        self.dtype = dtype
        self.abstract = abstract
        self.params: dict = {}
        self.specs: dict = {}

    def key_for(self, name: str | int) -> jax.Array | None:
        if self.abstract:
            return None
        return path_key(self._key, name)

    def _put(self, name, shape, dtype, make):
        if self.abstract:
            self.params[name] = jax.ShapeDtypeStruct(tuple(shape), dtype)
        else:
            self.params[name] = make()

    def dense(self, name: str, shape, spec: P, scale: float | None = None,
              dtype=None):
        fan_in = shape[0] if len(shape) > 1 else shape[-1]
        scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
        dt = dtype or self.dtype
        self._put(name, shape, dt,
                  lambda: (jax.random.normal(self.key_for(name), shape,
                                             jnp.float32) * scale).astype(dt))
        self.specs[name] = spec

    def zeros(self, name: str, shape, spec: P, dtype=None):
        dt = dtype or self.dtype
        self._put(name, shape, dt, lambda: jnp.zeros(shape, dt))
        self.specs[name] = spec

    def ones(self, name: str, shape, spec: P, dtype=None):
        dt = dtype or self.dtype
        self._put(name, shape, dt, lambda: jnp.ones(shape, dt))
        self.specs[name] = spec

    def const(self, name: str, value, spec: P):
        if self.abstract:
            self.params[name] = jax.ShapeDtypeStruct(value.shape, value.dtype)
        else:
            self.params[name] = value
        self.specs[name] = spec

    def child(self, name: str) -> "ParamBuilder":
        sub = ParamBuilder(self.key_for(name), self.dtype, self.abstract)
        self.params[name] = sub.params
        self.specs[name] = sub.specs
        return sub

    def stacked(self, name: str, n: int, init_fn) -> None:
        """Stack ``n`` copies of a sub-module's params along a new leading
        layer axis (for ``lax.scan`` over layers).  Layer ``i`` builds from
        ``path_key(path_key(subtree, name), i)``."""
        if self.abstract:
            b = ParamBuilder(None, self.dtype, abstract=True)
            init_fn(b)
            self.params[name] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n, *s.shape), s.dtype),
                b.params)
            spec = b.specs
        else:
            base = self.key_for(name)
            subs = []
            spec = None
            for i in range(n):
                b = ParamBuilder(path_key(base, i), self.dtype)
                init_fn(b)
                subs.append(b.params)
                spec = b.specs
            self.params[name] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *subs)

        def lift(s: P) -> P:
            return P(None, *s)

        self.specs[name] = jax.tree.map(
            lift, spec, is_leaf=lambda x: isinstance(x, P))


def zero_rows_from(b: ParamBuilder, name: str, start: int) -> None:
    """Zero leaf ``name``'s rows [start:] (padding rows must not carry
    random init — e.g. embedding vocab padding).  No-op when abstract or
    when there is no padding."""
    w = b.params.get(name)
    if b.abstract or w is None or start >= w.shape[0]:
        return
    b.params[name] = w.at[start:, :].set(0)


def zero_cols_from(b: ParamBuilder, name: str, start: int) -> None:
    """Zero leaf ``name``'s trailing-dim columns [start:]."""
    w = b.params.get(name)
    if b.abstract or w is None or start >= w.shape[-1]:
        return
    b.params[name] = w.at[..., start:].set(0)

"""Attention modules: GQA (RoPE, optional QKV bias), cross-attention, MLA.

Sharding: q-heads column-parallel over ``model`` when divisible
(``ctx.shard_heads``), KV projections always replicated (DESIGN.md §5);
decode uses the sequence-sharded cache from ``layers``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import (ArchConfig, ParamBuilder, ShardCtx,
                                 zero_cols_from, zero_rows_from)
from repro.models import layers as L


# ---------------------------------------------------------------------------
# GQA self-attention
# ---------------------------------------------------------------------------

def _H(cfg: ArchConfig, ctx: ShardCtx) -> int:
    """Effective (possibly padded) q-head count."""
    return ctx.h_pad or cfg.n_heads


def _zero_pad_cols(sub, name: str, start_col: int):
    """Zero the padded-head columns so the init function IS the spec arch."""
    zero_cols_from(sub, f"{name}_w", start_col)
    zero_cols_from(sub, f"{name}_b", start_col)


def _zero_pad_rows(sub, name: str, start_row: int):
    zero_rows_from(sub, f"{name}_w", start_row)


def init_gqa(b: ParamBuilder, name: str, cfg: ArchConfig, ctx: ShardCtx,
             *, cross: bool = False):
    sub = b.child(name)
    d, H, hd, kv = cfg.d_model, _H(cfg, ctx), cfg.hd, cfg.n_kv
    q_mode = "col" if ctx.shard_heads else "rep"
    o_mode = "row" if ctx.shard_heads else "rep"
    L.init_linear(sub, "q", d, H * hd, mode=q_mode, tp=ctx.tp, bias=cfg.qkv_bias)
    L.init_linear(sub, "k", d, kv * hd, mode="rep", tp=ctx.tp, bias=cfg.qkv_bias)
    L.init_linear(sub, "v", d, kv * hd, mode="rep", tp=ctx.tp, bias=cfg.qkv_bias)
    L.init_linear(sub, "o", H * hd, d, mode=o_mode, tp=ctx.tp)
    if ctx.h_pad:
        _zero_pad_cols(sub, "q", cfg.n_heads * hd)
        _zero_pad_rows(sub, "o", cfg.n_heads * hd)


def _heads_local(cfg: ArchConfig, ctx: ShardCtx) -> int:
    H = _H(cfg, ctx)
    return H // ctx.tp if ctx.shard_heads else H


def _kv_slice(k, v, cfg: ArchConfig, ctx: ShardCtx, axis: int):
    """Slice the KV heads this rank's q-head shard actually uses.

    KV projections are replicated (DESIGN.md §5), so every rank computes all
    KV heads; with q-heads sharded, rank r's local q heads [r*Hl, (r+1)*Hl)
    attend to kv heads [r*Hl//g, ...) where g = H // KV.  Requires Hl % g == 0
    or g % Hl == 0 — true for the whole assigned zoo at tp in {1..16}.
    """
    if not ctx.shard_heads or ctx.tp == 1:
        return k, v
    H, KV = _H(cfg, ctx), cfg.n_kv
    Hl = H // ctx.tp
    g = H // KV
    if Hl >= g:
        assert Hl % g == 0, (Hl, g)
        count = Hl // g
    else:
        assert g % Hl == 0, (Hl, g)
        count = 1
    r = ctx.tp_rank()
    start = (r * Hl) // g
    k = jax.lax.dynamic_slice_in_dim(k, start, count, axis)
    v = jax.lax.dynamic_slice_in_dim(v, start, count, axis)
    return k, v


def gqa_train(p, name, x, cfg: ArchConfig, ctx: ShardCtx, *,
              positions=None, window: int = 0, causal: bool = True,
              kv_src=None, use_rope: bool = True):
    """Training / prefill attention. ``kv_src`` (e.g. encoder output) makes
    this cross-attention (no rope on kv, no causal mask)."""
    sub = p[name]
    B, S, _ = x.shape
    Hl, hd, kv = _heads_local(cfg, ctx), cfg.hd, cfg.n_kv
    src = x if kv_src is None else kv_src
    q = L.linear_col(sub, "q", x).reshape(B, S, Hl, hd)
    k = L.linear_rep(sub, "k", src).reshape(B, src.shape[1], kv, hd)
    v = L.linear_rep(sub, "v", src).reshape(B, src.shape[1], kv, hd)
    if use_rope and kv_src is None:
        pos = positions if positions is not None else jnp.arange(S)
        q = L.rope(q, pos, cfg.rope_theta)
        k = L.rope(k, pos, cfg.rope_theta)
    k, v = _kv_slice(k, v, cfg, ctx, axis=2)
    out = L.flash_attention(q, k, v, causal=causal and kv_src is None,
                            window=window)
    out = out.reshape(B, S, Hl * hd)
    return (L.linear_row(sub, "o", out, ctx) if ctx.shard_heads
            else L.linear_rep(sub, "o", out))


def gqa_make_cache(cfg: ArchConfig, ctx: ShardCtx, batch: int, seq: int,
                   dtype=jnp.bfloat16):
    """Per-layer cache pytree (sequence-sharded over model: local Sl)."""
    tp = ctx.tp if ctx.decode_seq_shard else 1
    sl = max(1, -(-seq // tp))
    kv, hd = cfg.n_kv, cfg.hd
    return {
        "k": jnp.zeros((batch, sl, kv, hd), dtype),
        "v": jnp.zeros((batch, sl, kv, hd), dtype),
        "pos": jnp.full((sl,), -1, jnp.int32),
    }


def gqa_prefill_cache(p, name, x, cfg: ArchConfig, ctx: ShardCtx):
    """Compute K/V for a full prompt and return the seq-sharded cache slice
    (round-robin: rank r owns positions r, r+tp, ...)."""
    sub = p[name]
    B, S, _ = x.shape
    kv, hd = cfg.n_kv, cfg.hd
    k = L.linear_rep(sub, "k", x).reshape(B, S, kv, hd)
    v = L.linear_rep(sub, "v", x).reshape(B, S, kv, hd)
    k = L.rope(k, jnp.arange(S), cfg.rope_theta)
    tp = ctx.tp if ctx.decode_seq_shard else 1
    r = ctx.tp_rank() if (ctx.tp > 1 and ctx.decode_seq_shard) else 0
    sl = -(-S // tp)
    slots = jnp.arange(sl) * tp + r          # my global positions
    safe = jnp.clip(slots, 0, S - 1)
    ok = slots < S
    return {
        "k": jnp.where(ok[None, :, None, None], k[:, safe], 0),
        "v": jnp.where(ok[None, :, None, None], v[:, safe], 0),
        "pos": jnp.where(ok, slots, -1).astype(jnp.int32),
    }


def gqa_decode(p, name, x, cache, t, cfg: ArchConfig, ctx: ShardCtx, *,
               window: int = 0):
    """One-token decode. x: [B, d]; t: current global position (scalar)."""
    sub = p[name]
    B = x.shape[0]
    Hl, hd, kv = _heads_local(cfg, ctx), cfg.hd, cfg.n_kv
    q = L.linear_col(sub, "q", x).reshape(B, Hl, hd)
    k = L.linear_rep(sub, "k", x).reshape(B, kv, hd)
    v = L.linear_rep(sub, "v", x).reshape(B, kv, hd)
    tpos = jnp.full((1,), t, jnp.int32)
    q = L.rope(q[:, None], tpos, cfg.rope_theta)[:, 0]
    k = L.rope(k[:, None], tpos, cfg.rope_theta)[:, 0]
    kc, vc, pc = L.cache_write(cache["k"], cache["v"], cache["pos"],
                               k, v, t, ctx)
    ku, vu = _kv_slice(kc, vc, cfg, ctx, axis=2)
    out = L.decode_attention(q, ku, vu, pc, t, ctx, window=window)
    out = out.reshape(B, Hl * hd)
    y = (L.linear_row(sub, "o", out, ctx) if ctx.shard_heads
         else L.linear_rep(sub, "o", out))
    return y, {"k": kc, "v": vc, "pos": pc}


def gqa_cross_decode(p, name, x, cross_cache, cfg: ArchConfig, ctx: ShardCtx):
    """Cross-attention during decode: KV precomputed from encoder output
    (replicated — encoder length is short, 1500 frames)."""
    sub = p[name]
    B = x.shape[0]
    Hl, hd = _heads_local(cfg, ctx), cfg.hd
    q = L.linear_col(sub, "q", x).reshape(B, 1, Hl, hd)
    ku, vu = _kv_slice(cross_cache["k"], cross_cache["v"], cfg, ctx, axis=2)
    out = L.flash_attention(q, ku, vu, causal=False)
    out = out.reshape(B, Hl * hd)
    return (L.linear_row(sub, "o", out, ctx) if ctx.shard_heads
            else L.linear_rep(sub, "o", out))


def gqa_make_cross_cache(p, name, enc_out, cfg: ArchConfig, ctx: ShardCtx):
    sub = p[name]
    B, S, _ = enc_out.shape
    kv, hd = cfg.n_kv, cfg.hd
    k = L.linear_rep(sub, "k", enc_out).reshape(B, S, kv, hd)
    v = L.linear_rep(sub, "v", enc_out).reshape(B, S, kv, hd)
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLA (Multi-head Latent Attention — minicpm3)
# ---------------------------------------------------------------------------

def init_mla(b: ParamBuilder, name: str, cfg: ArchConfig, ctx: ShardCtx):
    sub = b.child(name)
    d, H = cfg.d_model, _H(cfg, ctx)
    hd_n, rd, vd = cfg.hd, cfg.mla_rope_dim, cfg.mla_v_dim
    qr, kvr = cfg.mla_q_rank, cfg.mla_kv_rank
    up_mode = "col" if ctx.shard_heads else "rep"
    o_mode = "row" if ctx.shard_heads else "rep"
    L.init_linear(sub, "q_down", d, qr, mode="rep", tp=ctx.tp)
    L.init_linear(sub, "q_up", qr, H * (hd_n + rd), mode=up_mode, tp=ctx.tp)
    L.init_linear(sub, "kv_down", d, kvr + rd, mode="rep", tp=ctx.tp)
    L.init_linear(sub, "kv_up", kvr, H * (hd_n + vd), mode=up_mode, tp=ctx.tp)
    L.init_linear(sub, "o", H * vd, d, mode=o_mode, tp=ctx.tp)
    L.init_rmsnorm(sub, "q_norm", qr)
    L.init_rmsnorm(sub, "kv_norm", kvr)
    if ctx.h_pad:
        _zero_pad_cols(sub, "q_up", cfg.n_heads * (hd_n + rd))
        _zero_pad_cols(sub, "kv_up", cfg.n_heads * (hd_n + vd))
        _zero_pad_rows(sub, "o", cfg.n_heads * vd)


def _mla_qkv(sub, x, cfg: ArchConfig, ctx: ShardCtx, positions):
    """Shared q / latent computation. Returns q [B,S,Hl,hd+rd],
    c [B,S,kvr], k_rope [B,S,rd]."""
    B, S, _ = x.shape
    Hl = _heads_local(cfg, ctx)
    hd_n, rd = cfg.hd, cfg.mla_rope_dim
    cq = L.rmsnorm(sub["q_norm"], L.linear_rep(sub, "q_down", x))
    q = L.linear_col(sub, "q_up", cq).reshape(B, S, Hl, hd_n + rd)
    kv_c = L.linear_rep(sub, "kv_down", x)
    c = L.rmsnorm(sub["kv_norm"], kv_c[..., :cfg.mla_kv_rank])
    k_rope = kv_c[..., cfg.mla_kv_rank:]
    q_nope, q_rope = q[..., :hd_n], q[..., hd_n:]
    q_rope = L.rope(q_rope, positions, cfg.rope_theta)
    k_rope = L.rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return jnp.concatenate([q_nope, q_rope], -1), c, k_rope


def mla_train(p, name, x, cfg: ArchConfig, ctx: ShardCtx, *,
              positions=None, window: int = 0):
    sub = p[name]
    B, S, _ = x.shape
    Hl = _heads_local(cfg, ctx)
    hd_n, rd, vd = cfg.hd, cfg.mla_rope_dim, cfg.mla_v_dim
    pos = positions if positions is not None else jnp.arange(S)
    q, c, k_rope = _mla_qkv(sub, x, cfg, ctx, pos)
    kv = L.linear_col(sub, "kv_up", c).reshape(B, S, Hl, hd_n + vd)
    k = jnp.concatenate(
        [kv[..., :hd_n], jnp.broadcast_to(k_rope[:, :, None, :],
                                          (B, S, Hl, rd))], -1)
    v = kv[..., hd_n:]
    out = L.flash_attention(q, k, v, causal=True, window=window)
    out = out.reshape(B, S, Hl * vd)
    return (L.linear_row(sub, "o", out, ctx) if ctx.shard_heads
            else L.linear_rep(sub, "o", out))


def mla_make_cache(cfg: ArchConfig, ctx: ShardCtx, batch: int, seq: int,
                   dtype=jnp.bfloat16):
    """Latent cache: c [B,Sl,kvr] + k_rope [B,Sl,rd] — the MLA memory win
    (no per-head K/V stored)."""
    tp = ctx.tp if ctx.decode_seq_shard else 1
    sl = max(1, -(-seq // tp))
    return {
        "c": jnp.zeros((batch, sl, cfg.mla_kv_rank), dtype),
        "kr": jnp.zeros((batch, sl, cfg.mla_rope_dim), dtype),
        "pos": jnp.full((sl,), -1, jnp.int32),
    }


def mla_decode(p, name, x, cache, t, cfg: ArchConfig, ctx: ShardCtx, *,
               window: int = 0):
    """Absorbed-matrices MLA decode against the latent cache."""
    sub = p[name]
    B = x.shape[0]
    Hl = _heads_local(cfg, ctx)
    hd_n, rd, vd, kvr = cfg.hd, cfg.mla_rope_dim, cfg.mla_v_dim, cfg.mla_kv_rank
    tpos = jnp.full((1,), t, jnp.int32)
    q, c_new, kr_new = _mla_qkv(sub, x[:, None], cfg, ctx, tpos)
    q, c_new, kr_new = q[:, 0], c_new[:, 0], kr_new[:, 0]
    q_nope, q_rope = q[..., :hd_n], q[..., hd_n:]
    # absorb W_uk: q' = q_nope @ W_uk  -> score against latent c directly
    w_up = sub["kv_up_w"].reshape(kvr, Hl, hd_n + vd)
    w_uk, w_uv = w_up[..., :hd_n], w_up[..., hd_n:]
    q_lat = jnp.einsum("bhd,khd->bhk", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))          # [B, Hl, kvr]
    # cache write (single "kv head" of latent + rope)
    cc, krc, pc = L.cache_write(
        cache["c"][:, :, None, :], cache["kr"][:, :, None, :], cache["pos"],
        c_new[:, None, :], kr_new[:, None, :], t, ctx)
    cc, krc = cc[:, :, 0, :], krc[:, :, 0, :]
    scale = 1.0 / math.sqrt(hd_n + rd)
    s = (jnp.einsum("bhk,bsk->bhs", q_lat, cc.astype(jnp.float32))
         + jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32),
                      krc.astype(jnp.float32))) * scale
    valid = (pc >= 0) & (pc <= t)
    if window > 0:
        valid = valid & (pc > t - window)
    s = jnp.where(valid[None, None, :], s, L.NEG)
    m = ctx.pmax_tp(jnp.max(s, axis=-1))
    pw = jnp.where(valid[None, None, :], jnp.exp(s - m[..., None]), 0.0)
    l = ctx.psum_tp(jnp.sum(pw, axis=-1))
    ctx_c = ctx.psum_tp(jnp.einsum("bhs,bsk->bhk", pw,
                                   cc.astype(jnp.float32)))
    ctx_c = ctx_c / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.einsum("bhk,khv->bhv", ctx_c,
                     w_uv.astype(jnp.float32)).astype(x.dtype)
    out = out.reshape(B, Hl * vd)
    y = (L.linear_row(sub, "o", out, ctx) if ctx.shard_heads
         else L.linear_rep(sub, "o", out))
    return y, {"c": cc, "kr": krc, "pos": pc}

"""Composable model builder: one entry point for all assigned architectures.

``build_model(cfg, ctx)`` returns a ``Model`` whose methods are the
*per-device* SPMD programs (they run inside ``shard_map``):

  train_loss(params, batch)          -> (loss, metrics)
  prefill(params, batch)             -> (logits_last, cache)
  decode(params, cache, tokens)      -> (next_tokens, logits_max, cache)
  init(key) / abstract()             -> params / (shapes, specs)
  make_cache(batch, cache_len, ...)  -> fresh decode cache

Design notes:
  * input embedding is UNTIED from the LM head: the input table is the
    row-sparse tensor Zen synchronizes (gather-backward => row-sparse grads,
    the paper's regime); the LM head is an ordinary column-parallel linear
    with dense grads.  Tying would densify the embedding grad and erase the
    paper's setting (DESIGN.md §4).
  * layers are stacked and scanned (``lax.scan`` + per-layer remat) to keep
    HLO size and compile time bounded at 62 layers.
  * audio (whisper) / vision (pixtral) frontends are stubs per the
    assignment: batches carry precomputed frame/patch embeddings.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.common import (ArchConfig, ParamBuilder, ShardCtx,
                                 make_ctx, zero_cols_from)
from repro.models import layers as L
from repro.models import attention as A
from repro.models import moe as M
from repro.models import ssm as S

AUX_LOSS_W = 0.01


# ---------------------------------------------------------------------------
# small helpers
# ---------------------------------------------------------------------------

def _mean_tree(t):
    return jax.tree.map(jnp.mean, t)


def _zeros(shape, dtype, abstract: bool):
    return (jax.ShapeDtypeStruct(tuple(shape), dtype) if abstract
            else jnp.zeros(shape, dtype))


def _stack_cache(make_one: Callable[[], Any], n: int, abstract: bool):
    one = make_one()
    if abstract:
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n, *s.shape), s.dtype), one)
    return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n, *a.shape)), one)


# ---------------------------------------------------------------------------
# decoder layer (dense / moe / mla kinds share this)
# ---------------------------------------------------------------------------

def _init_decoder_layer(b: ParamBuilder, cfg: ArchConfig, ctx: ShardCtx,
                        *, cross: bool = False):
    d = cfg.d_model
    L.init_rmsnorm(b, "ln1", d)
    if cfg.mla_q_rank:
        A.init_mla(b, "attn", cfg, ctx)
    else:
        A.init_gqa(b, "attn", cfg, ctx)
    if cross:
        L.init_rmsnorm(b, "lnx", d)
        A.init_gqa(b, "xattn", cfg, ctx, cross=True)
    L.init_rmsnorm(b, "ln2", d)
    if cfg.kind == "moe":
        M.init_moe(b, "ffn", cfg, ctx)
    elif cfg.kind == "enc_dec":
        L.init_gelu_mlp(b, "ffn", d, cfg.d_ff, ctx.tp)
    else:
        L.init_swiglu(b, "ffn", d, cfg.d_ff, ctx.tp)


def _ffn(pl, x, cfg: ArchConfig, ctx: ShardCtx):
    if cfg.kind == "moe":
        return M.moe_ffn(pl, "ffn", x, cfg, ctx)
    if cfg.kind == "enc_dec":
        return L.gelu_mlp(pl, "ffn", x, ctx), {}
    return L.swiglu(pl, "ffn", x, ctx), {}


def _decoder_layer_train(pl, x, cfg: ArchConfig, ctx: ShardCtx, *,
                         positions=None, window: int = 0, enc_out=None):
    h = L.rmsnorm(pl["ln1"], x)
    if cfg.mla_q_rank:
        h = A.mla_train(pl, "attn", h, cfg, ctx, positions=positions,
                        window=window)
    else:
        h = A.gqa_train(pl, "attn", h, cfg, ctx, positions=positions,
                        window=window)
    x = x + h
    if enc_out is not None:
        x = x + A.gqa_train(pl, "xattn", L.rmsnorm(pl["lnx"], x), cfg, ctx,
                            kv_src=enc_out, use_rope=False)
    y, stats = _ffn(pl, L.rmsnorm(pl["ln2"], x), cfg, ctx)
    return x + y, stats


def _decoder_layer_decode(pl, x, cache, t, cfg: ArchConfig, ctx: ShardCtx, *,
                          window: int = 0, cross_cache=None):
    h = L.rmsnorm(pl["ln1"], x[:, None])[:, 0]
    if cfg.mla_q_rank:
        h, c2 = A.mla_decode(pl, "attn", h, cache, t, cfg, ctx, window=window)
    else:
        h, c2 = A.gqa_decode(pl, "attn", h, cache, t, cfg, ctx, window=window)
    x = x + h
    if cross_cache is not None:
        x = x + A.gqa_cross_decode(
            pl, "xattn", L.rmsnorm(pl["lnx"], x[:, None])[:, 0],
            cross_cache, cfg, ctx)
    y, _ = _ffn(pl, L.rmsnorm(pl["ln2"], x[:, None]), cfg, ctx)
    return x + y[:, 0], c2


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    ctx: ShardCtx
    sparse_paths: tuple = ("embed/table",)

    # ---- params ------------------------------------------------------------

    def _build(self, b: ParamBuilder):
        cfg, ctx = self.cfg, self.ctx
        vp = cfg.vocab_padded
        L.init_embedding(b, "embed", cfg.vocab, vp, cfg.d_model)
        L.init_linear(b, "lm_head", cfg.d_model, vp, mode="col", tp=ctx.tp)
        # padded LM-head columns: zero-init (masked out of the logsumexp /
        # argmax anyway, so they receive zero gradient and stay zero)
        zero_cols_from(b, "lm_head_w", cfg.vocab)
        L.init_rmsnorm(b, "ln_f", cfg.d_model)
        if cfg.kind == "ssm":
            b.stacked("layers", cfg.n_layers, functools.partial(
                self._init_ssm_layer))
        elif cfg.kind == "hybrid":
            self._build_hybrid(b)
        elif cfg.kind == "enc_dec":
            self._build_enc_dec(b)
        else:
            cross = False
            b.stacked("layers", cfg.n_layers, functools.partial(
                _init_decoder_layer, cfg=cfg, ctx=ctx, cross=cross))
        if cfg.kind == "vlm":
            L.init_linear(b, "vis_proj", cfg.d_model, cfg.d_model,
                          mode="rep", tp=ctx.tp)

    def _init_ssm_layer(self, b: ParamBuilder):
        L.init_rmsnorm(b, "ln1", self.cfg.d_model)
        S.init_mamba2(b, "mixer", self.cfg, self.ctx)

    def _build_hybrid(self, b: ParamBuilder):
        cfg, ctx = self.cfg, self.ctx
        every = cfg.shared_attn_every
        self.n_groups = cfg.n_layers // every
        self.n_tail = cfg.n_layers - self.n_groups * every

        def group(bg: ParamBuilder):
            bg.stacked("inner", every, self._init_ssm_layer)

        b.stacked("groups", self.n_groups, group)
        if self.n_tail:
            b.stacked("tail", self.n_tail, self._init_ssm_layer)
        shared = b.child("shared")
        _init_decoder_layer(shared, dataclasses.replace(cfg, kind="dense"),
                            ctx)

    def _build_enc_dec(self, b: ParamBuilder):
        cfg, ctx = self.cfg, self.ctx

        def enc_layer(be: ParamBuilder):
            L.init_rmsnorm(be, "ln1", cfg.d_model)
            A.init_gqa(be, "attn", cfg, ctx)
            L.init_rmsnorm(be, "ln2", cfg.d_model)
            L.init_gelu_mlp(be, "ffn", cfg.d_model, cfg.d_ff, ctx.tp)

        b.stacked("enc_layers", cfg.n_enc_layers, enc_layer)
        L.init_rmsnorm(b, "ln_enc", cfg.d_model)
        b.stacked("layers", cfg.n_layers, functools.partial(
            _init_decoder_layer, cfg=cfg, ctx=ctx, cross=True))

    def init(self, key) -> tuple[Any, Any]:
        b = ParamBuilder(key, self.cfg.dtype)
        self._build(b)
        return b.params, b.specs

    def abstract(self) -> tuple[Any, Any]:
        b = ParamBuilder(None, self.cfg.dtype, abstract=True)
        self._build(b)
        return b.params, b.specs

    # ---- forward (shared trunk) ---------------------------------------------

    def _embed(self, params, tokens):
        return L.embed_lookup(params, "embed", tokens, self.ctx)

    def _head_logits(self, params, x):
        """Local LM-head logits with padded vocab columns masked to NEG —
        padding can never win an argmax or leak into a softmax."""
        logits_l = L.linear_col(params, "lm_head", x)
        return L.mask_padded_logits(logits_l, self.ctx, self.cfg.vocab)

    def _trunk(self, params, x, *, positions=None, window: int = 0,
               enc_out=None):
        """Run the layer stack on [B, S, d]; returns (x, stats)."""
        cfg, ctx = self.cfg, self.ctx
        if cfg.kind == "ssm":
            def body(carry, pl):
                y = carry + S.mamba2_train(
                    pl, "mixer", L.rmsnorm(pl["ln1"], carry), cfg, ctx)
                return y, {}
            x, _ = lax.scan(jax.checkpoint(body), x, params["layers"])
            return x, {}
        if cfg.kind == "hybrid":
            return self._trunk_hybrid(params, x), {}
        # dense / moe / mla / enc-dec decoder / vlm
        def body_stats(carry, pl):
            y, stats = _decoder_layer_train(
                pl, carry, cfg, ctx, positions=positions, window=window,
                enc_out=enc_out)
            return y, stats

        x, stats = lax.scan(jax.checkpoint(body_stats), x, params["layers"])
        return x, _mean_tree(stats) if stats else {}

    def _trunk_hybrid(self, params, x):
        cfg, ctx = self.cfg, self.ctx
        dense_cfg = dataclasses.replace(cfg, kind="dense")
        shared = params["shared"]

        def ssm_body(carry, pl):
            return carry + S.mamba2_train(
                pl, "mixer", L.rmsnorm(pl["ln1"], carry), cfg, ctx), None

        def group_body(carry, pg):
            y, _ = _decoder_layer_train(shared, carry, dense_cfg, ctx)
            y, _ = lax.scan(jax.checkpoint(ssm_body), y, pg["inner"])
            return y, None

        x, _ = lax.scan(group_body, x, params["groups"])
        if self.n_tail:
            x, _ = lax.scan(jax.checkpoint(ssm_body), x, params["tail"])
        return x

    def _encode(self, params, frames):
        """Whisper encoder over stub frame embeddings [B, T, d]."""
        cfg, ctx = self.cfg, self.ctx
        Tt = frames.shape[1]
        pos = jnp.arange(Tt)
        half = cfg.d_model // 2
        freqs = 10000.0 ** (-jnp.arange(half, dtype=jnp.float32) / half)
        ang = pos[:, None].astype(jnp.float32) * freqs[None]
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)
        x = frames + pe[None].astype(frames.dtype)

        def body(carry, pl):
            h = A.gqa_train(pl, "attn", L.rmsnorm(pl["ln1"], carry), cfg, ctx,
                            causal=False, use_rope=False)
            y = carry + h
            y = y + L.gelu_mlp(pl, "ffn", L.rmsnorm(pl["ln2"], y), ctx)
            return y, None

        x, _ = lax.scan(jax.checkpoint(body), x, params["enc_layers"])
        return L.rmsnorm(params["ln_enc"], x)

    # ---- training ------------------------------------------------------------

    def train_loss(self, params, batch) -> tuple[jnp.ndarray, dict]:
        """batch: tokens [B,S], labels [B,S] (-1 = masked); enc_dec adds
        frames [B,T,d]; vlm adds patches [B,P,d]."""
        cfg, ctx = self.cfg, self.ctx
        tokens, labels = batch["tokens"], batch["labels"]
        x = self._embed(params, tokens)
        positions = None
        enc_out = None
        if cfg.kind == "vlm":
            pat = L.linear_rep(params, "vis_proj", batch["patches"])
            x = jnp.concatenate([pat.astype(x.dtype), x], axis=1)
            positions = jnp.arange(x.shape[1])
            labels = jnp.concatenate(
                [jnp.full(pat.shape[:2], -1, labels.dtype), labels], axis=1)
        if cfg.kind == "enc_dec":
            enc_out = self._encode(params, batch["frames"])
        x, stats = self._trunk(params, x, positions=positions,
                               enc_out=enc_out)
        x = L.rmsnorm(params["ln_f"], x)
        if cfg.kind == "vlm":          # drop patch positions before the head
            x = x[:, batch["patches"].shape[1]:]
            labels = labels[:, batch["patches"].shape[1]:]
        loss = L.lm_head_loss_chunked(params, "lm_head", x, labels, ctx,
                                      mask=labels >= 0, valid_vocab=cfg.vocab)
        metrics = {"loss": loss, **{k: jnp.asarray(v) for k, v in
                                    (stats or {}).items()}}
        if cfg.kind == "moe" and "moe/aux_loss" in metrics:
            loss = loss + AUX_LOSS_W * metrics["moe/aux_loss"]
        return loss, metrics

    # ---- serving ---------------------------------------------------------------

    def make_cache(self, batch_local: int, cache_len: int, *,
                   abstract: bool = False):
        cfg, ctx = self.cfg, self.ctx
        def mk_attn_concrete():
            return A.gqa_make_cache(cfg, ctx, batch_local, cache_len,
                                    dtype=cfg.dtype)

        if abstract:
            def mk_attn():
                return jax.tree.map(
                    lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                    jax.eval_shape(mk_attn_concrete))
        else:
            mk_attn = mk_attn_concrete
        cache: dict[str, Any] = {"t": _zeros((), jnp.int32, abstract)}
        if cfg.kind == "ssm":
            cache["layers"] = _stack_cache(
                lambda: S.mamba2_make_cache(cfg, ctx, batch_local,
                                            dtype=cfg.dtype),
                cfg.n_layers, abstract)
        elif cfg.kind == "hybrid":
            every = cfg.shared_attn_every
            ng = cfg.n_layers // every
            nt = cfg.n_layers - ng * every
            cache["ssm"] = _stack_cache(
                lambda: _stack_cache(
                    lambda: S.mamba2_make_cache(cfg, ctx, batch_local,
                                                dtype=cfg.dtype),
                    every, abstract),
                ng, abstract)
            if nt:
                cache["ssm_tail"] = _stack_cache(
                    lambda: S.mamba2_make_cache(cfg, ctx, batch_local,
                                                dtype=cfg.dtype),
                    nt, abstract)
            cache["attn"] = _stack_cache(
                lambda: A.gqa_make_cache(cfg, ctx, batch_local, cache_len,
                                         dtype=cfg.dtype),
                ng, abstract)
        elif cfg.mla_q_rank:
            cache["layers"] = _stack_cache(
                lambda: A.mla_make_cache(cfg, ctx, batch_local, cache_len,
                                         dtype=cfg.dtype),
                cfg.n_layers, abstract)
        else:
            cache["layers"] = _stack_cache(mk_attn, cfg.n_layers, abstract)
        if cfg.kind == "enc_dec":
            kv, hd = cfg.n_kv, cfg.hd
            cache["cross"] = _zeros(
                (cfg.n_layers, 2, batch_local, cfg.enc_len, kv, hd),
                cfg.dtype, abstract)
        return cache

    def prime_cross_cache(self, params, frames):
        """Whisper: encode frames and precompute per-layer cross K/V
        ([L, 2, B, enc_len, kv, hd])."""
        enc = self._encode(params, frames)

        def one(_, pl):
            cc = A.gqa_make_cross_cache(pl, "xattn", enc, self.cfg, self.ctx)
            return None, jnp.stack([cc["k"], cc["v"]])

        _, cross = lax.scan(one, None, params["layers"])
        return cross

    def decode(self, params, cache, tokens, *, window: int = 0):
        """One decode step. tokens [B, 1] -> (next [B,1], logit_max, cache)."""
        cfg, ctx = self.cfg, self.ctx
        t = cache["t"]
        x = self._embed(params, tokens)[:, 0]

        if cfg.kind == "ssm":
            def body(carry, inp):
                pl, cl = inp
                y = L.rmsnorm(pl["ln1"], carry[:, None])[:, 0]
                h, c2 = S.mamba2_decode(pl, "mixer", y, cl, cfg, ctx)
                return carry + h, c2
            x, new_layers = lax.scan(body, x, (params["layers"],
                                               cache["layers"]))
            new_cache = {"t": t + 1, "layers": new_layers}
        elif cfg.kind == "hybrid":
            x, new_cache = self._decode_hybrid(params, cache, x, t,
                                               window=window)
        else:
            cross = cache.get("cross")

            def body(carry, inp):
                if cross is None:
                    pl, cl = inp
                    cc = None
                else:
                    pl, cl, cx = inp
                    cc = {"k": cx[0], "v": cx[1]}
                y, c2 = _decoder_layer_decode(pl, carry, cl, t, cfg, ctx,
                                              window=window, cross_cache=cc)
                return y, c2

            xs = ((params["layers"], cache["layers"]) if cross is None
                  else (params["layers"], cache["layers"], cross))
            x, new_layers = lax.scan(body, x, xs)
            new_cache = dict(cache, t=t + 1, layers=new_layers)

        x = L.rmsnorm(params["ln_f"], x)
        logits_l = self._head_logits(params, x)            # [B, V/tp]
        # greedy global argmax over the vocab-sharded logits (padded
        # columns are already masked to NEG and cannot be selected)
        lf = logits_l.astype(jnp.float32)
        m_l = jnp.max(lf, axis=-1)
        i_l = jnp.argmax(lf, axis=-1).astype(jnp.int32)
        m = ctx.pmax_tp(m_l)
        off = ctx.tp_rank() * lf.shape[-1] if ctx.tp > 1 else 0
        cand = jnp.where(m_l >= m, i_l + off, 0)
        nxt = ctx.pmax_tp(cand)[:, None]
        return nxt, m, new_cache

    def _decode_hybrid(self, params, cache, x, t, *, window: int = 0):
        cfg, ctx = self.cfg, self.ctx
        dense_cfg = dataclasses.replace(cfg, kind="dense")
        shared = params["shared"]

        def ssm_body(carry, inp):
            pl, cl = inp
            y = L.rmsnorm(pl["ln1"], carry[:, None])[:, 0]
            h, c2 = S.mamba2_decode(pl, "mixer", y, cl, cfg, ctx)
            return carry + h, c2

        def group_body(carry, inp):
            pg, ssm_c, attn_c = inp
            y, ac2 = _decoder_layer_decode(shared, carry, attn_c, t,
                                           dense_cfg, ctx, window=window)
            y, sc2 = lax.scan(ssm_body, y, (pg["inner"], ssm_c))
            return y, (sc2, ac2)

        x, (new_ssm, new_attn) = lax.scan(
            group_body, x, (params["groups"], cache["ssm"], cache["attn"]))
        new_cache = dict(cache, t=t + 1, ssm=new_ssm, attn=new_attn)
        if self.cfg.n_layers % self.cfg.shared_attn_every:
            x, new_tail = lax.scan(ssm_body, x,
                                   (params["tail"], cache["ssm_tail"]))
            new_cache["ssm_tail"] = new_tail
        return x, new_cache

    def _prefill_hybrid(self, params, x, Sfull):
        cfg, ctx = self.cfg, self.ctx
        dense_cfg = dataclasses.replace(cfg, kind="dense")
        shared = params["shared"]

        def ssm_body(carry, pl):
            h = L.rmsnorm(pl["ln1"], carry)
            y, cl = S.mamba2_train(pl, "mixer", h, cfg, ctx,
                                   return_cache=True)
            return carry + y, cl

        def group_body(carry, pg):
            h = carry
            kv = A.gqa_prefill_cache(
                shared, "attn", L.rmsnorm(shared["ln1"], h), cfg, ctx)
            y, _ = _decoder_layer_train(shared, h, dense_cfg, ctx)
            y, sc = lax.scan(ssm_body, y, pg["inner"])
            return y, (sc, kv)

        x, (ssm_c, attn_c) = lax.scan(group_body, x, params["groups"])
        cache = {"t": jnp.asarray(Sfull, jnp.int32), "ssm": ssm_c,
                 "attn": attn_c}
        if self.n_tail:
            x, tail_c = lax.scan(ssm_body, x, params["tail"])
            cache["ssm_tail"] = tail_c
        x_last = L.rmsnorm(params["ln_f"], x[:, -1])
        logits_l = self._head_logits(params, x_last)
        return logits_l, cache

    # ---- prefill -----------------------------------------------------------------

    def prefill(self, params, batch):
        """Forward the whole prompt, return (last-token logits_l, cache).

        The produced KV cache is sequence-sharded over model (round-robin),
        matching the decode layout.
        """
        cfg, ctx = self.cfg, self.ctx
        tokens = batch["tokens"]
        B, Ss = tokens.shape
        x = self._embed(params, tokens)
        positions = None
        if cfg.kind == "vlm":
            pat = L.linear_rep(params, "vis_proj", batch["patches"])
            x = jnp.concatenate([pat.astype(x.dtype), x], axis=1)
            positions = jnp.arange(x.shape[1])
        Sfull = x.shape[1]

        if cfg.kind == "ssm":
            def body(carry, pl):
                h = L.rmsnorm(pl["ln1"], carry)
                y, cl = S.mamba2_train(pl, "mixer", h, cfg, ctx,
                                       return_cache=True)
                return carry + y, cl
            x, layer_caches = lax.scan(body, x, params["layers"])
            x_last = L.rmsnorm(params["ln_f"], x[:, -1])
            logits_l = self._head_logits(params, x_last)
            return logits_l, {"t": jnp.asarray(Sfull, jnp.int32),
                              "layers": layer_caches}

        if cfg.kind == "hybrid":
            return self._prefill_hybrid(params, x, Sfull)

        if cfg.kind == "enc_dec":
            enc_out = self._encode(params, batch["frames"])

            def body_ed(carry, pl):
                h = carry
                kv = A.gqa_prefill_cache(
                    pl, "attn", L.rmsnorm(pl["ln1"], h), cfg, ctx)
                cc = A.gqa_make_cross_cache(
                    pl, "xattn", enc_out, cfg, ctx)
                y, _ = _decoder_layer_train(pl, h, cfg, ctx, enc_out=enc_out)
                return y, (kv, jnp.stack([cc["k"], cc["v"]]))
            x, (layer_caches, cross) = lax.scan(body_ed, x, params["layers"])
            x_last = L.rmsnorm(params["ln_f"], x[:, -1])
            logits_l = self._head_logits(params, x_last)
            return logits_l, {"t": jnp.asarray(Sfull, jnp.int32),
                              "layers": layer_caches, "cross": cross}

        # attention archs: run trunk while collecting per-layer K/V shards
        def body(carry, pl):
            h = carry
            y, _ = _decoder_layer_train(pl, h, cfg, ctx, positions=positions)
            kv = A.gqa_prefill_cache(
                pl, "attn", L.rmsnorm(pl["ln1"], h), cfg, ctx) \
                if not cfg.mla_q_rank else None
            return y, kv

        if cfg.mla_q_rank:
            # latent cache prefill for MLA
            def body_mla(carry, pl):
                h = carry
                y, _ = _decoder_layer_train(pl, h, cfg, ctx)
                xin = L.rmsnorm(pl["ln1"], h)
                kv_c = L.linear_rep(pl["attn"], "kv_down", xin)
                c = L.rmsnorm(pl["attn"]["kv_norm"],
                              kv_c[..., :cfg.mla_kv_rank])
                kr = L.rope(kv_c[:, :, None, cfg.mla_kv_rank:],
                            jnp.arange(h.shape[1]), cfg.rope_theta)[:, :, 0]
                tp = ctx.tp if ctx.decode_seq_shard else 1
                r = ctx.tp_rank() if (ctx.tp > 1 and ctx.decode_seq_shard) else 0
                sl = -(-Sfull // tp)
                slots = jnp.arange(sl) * tp + r
                safe = jnp.clip(slots, 0, Sfull - 1)
                ok = (slots < Sfull)
                return y, {
                    "c": jnp.where(ok[None, :, None], c[:, safe], 0),
                    "kr": jnp.where(ok[None, :, None], kr[:, safe], 0),
                    "pos": jnp.where(ok, slots, -1).astype(jnp.int32),
                }
            x, layer_caches = lax.scan(body_mla, x, params["layers"])
        else:
            x, layer_caches = lax.scan(body, x, params["layers"])
        x_last = L.rmsnorm(params["ln_f"], x[:, -1])
        logits_l = self._head_logits(params, x_last)
        cache = {"t": jnp.asarray(Sfull, jnp.int32), "layers": layer_caches}
        return logits_l, cache


def build_model(cfg: ArchConfig, ctx: ShardCtx) -> Model:
    m = Model(cfg=cfg, ctx=ctx)
    if cfg.kind == "hybrid":
        every = cfg.shared_attn_every
        m.n_groups = cfg.n_layers // every
        m.n_tail = cfg.n_layers - m.n_groups * every
    return m


def assert_mesh_invariant_params(cfg: ArchConfig, ctx: ShardCtx,
                                 shapes=None) -> None:
    """Enforce the DESIGN.md §9 contract: the *global* parameter pytree
    (paths, shapes, dtypes) must be identical to the tp=1 reference build.

    Runs on every ``build_program`` (abstract builds only — no allocation),
    so a layer init that silently makes a global shape depend on the mesh
    fails loudly at build time instead of surfacing as a cross-mesh loss
    mismatch three experiments later.  The opt-in ``h_pad`` layout is the
    one documented exception (it changes global shapes by design).
    """
    if ctx.h_pad:
        return
    if shapes is None:
        shapes = build_model(cfg, ctx).abstract()[0]
    ref_ctx = make_ctx(cfg, 1, 1)
    ref_shapes = build_model(cfg, ref_ctx).abstract()[0]
    got = jax.tree_util.tree_flatten_with_path(shapes)[0]
    ref = jax.tree_util.tree_flatten_with_path(ref_shapes)[0]
    bad = []
    for (kp, s), (rkp, rs) in zip(got, ref):
        if kp != rkp or s.shape != rs.shape or s.dtype != rs.dtype:
            bad.append(f"{jax.tree_util.keystr(kp)}: "
                       f"{s.shape}/{s.dtype} != {rs.shape}/{rs.dtype} "
                       f"(tp={ctx.tp} vs tp=1)")
    if len(got) != len(ref):
        bad.append(f"leaf count {len(got)} != {len(ref)} (tp={ctx.tp} "
                   f"vs tp=1)")
    if bad:
        raise AssertionError(
            f"config '{cfg.name}': global param pytree depends on the mesh "
            f"— violates the TP mesh-invariance contract (DESIGN.md §9):\n  "
            + "\n  ".join(bad[:20]))

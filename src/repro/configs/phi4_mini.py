"""Phi-4-mini 3.8B: dense RoPE SwiGLU GQA [arXiv:2412.08905]."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="phi4-mini-3.8b", kind="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv=8, head_dim=128,
    d_ff=8192, vocab=200064,
    source="arXiv:2412.08905",
)

"""MiniCPM3-4B: MLA (multi-head latent attention), 62 layers
[hf:openbmb/MiniCPM3-4B]."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="minicpm3-4b", kind="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv=40, head_dim=64,
    d_ff=6400, vocab=73448,
    mla_q_rank=768, mla_kv_rank=256, mla_rope_dim=32, mla_v_dim=64,
    source="hf:openbmb/MiniCPM3-4B",
)

"""Whisper-medium transformer backbone (enc-dec); conv/mel frontend is a
stub — batches carry precomputed frame embeddings [arXiv:2212.04356]."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", kind="enc_dec",
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv=16,
    head_dim=64, d_ff=4096, vocab=51865, qkv_bias=True, enc_len=1500,
    source="arXiv:2212.04356",
)

"""Qwen2-0.5B: dense GQA (kv=2), QKV bias [arXiv:2407.10671]."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-0.5b", kind="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv=2, head_dim=64,
    d_ff=4864, vocab=151936, qkv_bias=True, rope_theta=1e6,
    source="arXiv:2407.10671",
)

"""Mamba2-370m: attention-free SSD (state-space duality)
[arXiv:2405.21060]."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m", kind="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv=0, d_ff=0,
    vocab=50280, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    head_dim=64,
    source="arXiv:2405.21060",
)

"""Phi-3.5-MoE (42B total / 6.6B active): 16-expert top-2
[hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", kind="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, head_dim=128,
    d_ff=6400, vocab=32064, n_experts=16, top_k=2,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)

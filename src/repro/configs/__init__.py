"""Assigned-architecture registry: ``get_config(name)`` / ``ALL_ARCHS``."""
from repro.configs import (
    olmoe_1b_7b, whisper_medium, qwen2_0_5b, phi3_5_moe, phi4_mini,
    mamba2_370m, zamba2_1_2b, pixtral_12b, qwen2_5_3b, minicpm3_4b,
)

_MODULES = [
    olmoe_1b_7b, whisper_medium, qwen2_0_5b, phi3_5_moe, phi4_mini,
    mamba2_370m, zamba2_1_2b, pixtral_12b, qwen2_5_3b, minicpm3_4b,
]

CONFIGS = {m.CONFIG.name: m.CONFIG for m in _MODULES}
ALL_ARCHS = list(CONFIGS)

# input shapes assigned to this paper
INPUT_SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, mode="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, mode="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, mode="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, mode="decode"),
}


def get_config(name: str):
    if name not in CONFIGS:
        raise KeyError(f"unknown arch '{name}'; known: {ALL_ARCHS}")
    return CONFIGS[name]

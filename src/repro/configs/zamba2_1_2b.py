"""Zamba2-1.2B: Mamba2 backbone + shared attention block every 6 layers
[arXiv:2411.15242]."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", kind="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv=32, head_dim=64,
    d_ff=8192, vocab=32000, ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    shared_attn_every=6,
    source="arXiv:2411.15242",
)

"""Pixtral-12B language backbone (mistral-nemo style); the pixtral-ViT
vision tower + projector are stubs — batches carry patch embeddings
[hf:mistralai/Pixtral-12B-2409]."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b", kind="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv=8, head_dim=160,
    d_ff=14336, vocab=131072, n_patches=256, rope_theta=1e7,
    source="hf:mistralai/Pixtral-12B-2409",
)

"""Qwen2.5-3B: dense GQA (kv=2), QKV bias [hf:Qwen/Qwen2.5-3B]."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-3b", kind="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv=2, head_dim=128,
    d_ff=11008, vocab=151936, qkv_bias=True, rope_theta=1e6,
    source="hf:Qwen/Qwen2.5-0.5B (family card, 3B sizes)",
)

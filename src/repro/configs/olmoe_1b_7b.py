"""OLMoE-1B-7B: 64-expert top-8 MoE [arXiv:2409.02060]."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", kind="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv=16, head_dim=128,
    d_ff=1024, vocab=50304, n_experts=64, top_k=8,
    source="arXiv:2409.02060",
)

"""Microbenchmark of the zen_sync hot path: per-stage encode/decode timings,
end-to-end simulate() latency per scheme, and the bucketed-vs-monolithic
trainer sync step (DESIGN.md §7), across densities and backends.

This seeds the repo's perf trajectory: results land in ``BENCH_sync.json``
(repo root) so regressions in the sparsification fast path are visible
PR-over-PR, not just claimed.  Timings are median-of-iters via
``time.perf_counter`` with ``block_until_ready`` (benchmarks.common.time_fn).
The CI bench gate replays ``--smoke`` and diffs stage timings against the
committed baseline (benchmarks.check_regression).

CSV lines also go to stdout for the benchmarks.run harness.

Run: ``PYTHONPATH=src python -m benchmarks.run micro_sync``
or   ``PYTHONPATH=src python -m benchmarks.micro_sync [out.json]
      [--smoke] [--json PATH]``
"""
from __future__ import annotations

import argparse
import functools
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    build_gradsync_run,
    emit,
    record_stage_times,
    synthetic_grad_tree,
    time_ab,
    time_fn,
)
from repro.core import formats, metrics, schemes
from repro.core.hashing import compact_indices, extract_partitions, hierarchical_hash
from repro.core.registry import BALANCED_BINS

M = 1 << 14          # scaled tensor (volumes scale linearly; see common.py)
N = 4                # simulated workers
DENSITIES = (0.01, 0.05, 0.2)
BACKENDS = ("xla", "pallas")  # pallas runs in interpret mode off-TPU
BUCKET_BYTES = 1 << 16  # bucketed-schedule byte budget for the e2e series


def _workers(m: int, density: float, seed: int = 0) -> jnp.ndarray:
    key = jax.random.PRNGKey(seed)
    masks = metrics.synth_sparse_masks(key, N, m, density)
    return jax.random.normal(key, (N, m)) * masks


def _record(results, name, us, **tags):
    emit(f"micro_sync/{name}", us, ",".join(f"{k}={v}" for k, v in tags.items()))
    results.append(dict(name=name, us=us, **tags))


def bench_stages(results: list) -> None:
    """Each fast-path stage in isolation, per backend."""
    density = 0.05
    g = _workers(M, density)[0]
    layout = schemes.make_zen_layout(M, N, density_budget=4 * density)
    lo = layout

    sparsify = jax.jit(
        lambda x: compact_indices(x != 0, lo.cap_index)[0])
    idx = sparsify(g)
    _record(results, "sparsify", time_fn(sparsify, g),
            stage="sparsify", backend="xla", density=density)

    for backend in BACKENDS:
        if backend == "pallas":
            hash_fn = functools.partial(
                hierarchical_hash, n=N, r1=lo.r1, r2=lo.r2, k=lo.k,
                backend="pallas", interpret=None,
                static_seeds=lo.static_seeds())
        else:
            hash_fn = functools.partial(
                hierarchical_hash, n=N, r1=lo.r1, r2=lo.r2, k=lo.k,
                seeds=lo.device_tables().seeds)
        part = hash_fn(idx)
        _record(results, f"hash[{backend}]", time_fn(hash_fn, idx),
                stage="hash", backend=backend, density=density)

        ext = jax.jit(functools.partial(
            extract_partitions, backend=backend, interpret=None))
        _record(results, f"extract[{backend}]", time_fn(ext, part),
                stage="extract", backend=backend, density=density)

        mask = jnp.asarray(
            np.random.default_rng(0).uniform(size=lo.cap_server)
            < N * density)
        pack = jax.jit(functools.partial(
            formats.bitmap_encode, backend=backend, interpret=None))
        words = pack(mask)
        _record(results, f"bitmap_pack[{backend}]", time_fn(pack, mask),
                stage="bitmap_pack", backend=backend, density=density)

        wordsN = jnp.tile(words[None], (N, 1))
        unpack = jax.jit(functools.partial(
            formats.bitmap_decode_batch, length=lo.cap_server,
            backend=backend, interpret=None))
        _record(results, f"bitmap_unpack[{backend}]", time_fn(unpack, wordsN),
                stage="bitmap_unpack", backend=backend, density=density)

        # commit-side stages in isolation (DESIGN.md §14): the server
        # aggregation scatter-add and the pull-capacity compaction were
        # the uncovered dispatch-tax stages the fused commit removes
        from repro.kernels import ops as kops

        rng = np.random.default_rng(1)
        C = N * (lo.r1 + lo.r2)
        lp_np = rng.integers(0, lo.cap_server, size=C).astype(np.int32)
        lp_np[rng.random(C) >= min(1.0, N * density)] = lo.cap_server
        lp = jnp.asarray(lp_np)
        push_v = jnp.asarray(
            rng.standard_normal(C).astype(np.float32)
            * (lp_np < lo.cap_server))
        scat = jax.jit(functools.partial(
            kops.batched_coo_reduce_op, backend=backend, interpret=None))
        buf0 = jnp.zeros(lo.cap_server, jnp.float32)
        buf = scat(buf0, lp, push_v)
        _record(results, f"scatter_add[{backend}]",
                time_fn(scat, buf0, lp, push_v),
                stage="scatter_add", backend=backend, density=density)

        if backend == "xla":  # compaction is an XLA cumsum on both routes
            comp = jax.jit(functools.partial(
                compact_indices, capacity=lo.r1 + lo.r2))
            _record(results, f"commit_compact[{backend}]",
                    time_fn(comp, buf != 0),
                    stage="commit_compact", backend=backend,
                    density=density)


def bench_end_to_end(results: list, densities=DENSITIES) -> None:
    """Full simulate() latency and wire volume per scheme and density."""
    cases = []  # (name, fn, kwargs, scheme, density, backend)
    for density in densities:
        cap = max(64, int(M * 2 * density))
        layout = schemes.make_zen_layout(
            M, N, density_budget=min(0.5, 4 * density))
        cases += [
            (f"dense[d={density}]", schemes.dense_sync, {},
             "dense", density, "xla"),
            (f"agsparse[d={density}]", schemes.agsparse_sync,
             dict(capacity=cap), "agsparse", density, "xla"),
            (f"sparcml[d={density}]", schemes.sparcml_sync,
             dict(n=N, capacity=cap), "sparcml", density, "xla"),
            (f"sparse_ps[d={density}]", schemes.sparse_ps_sync,
             dict(n=N, cap_push=cap, cap_pull=cap),
             "sparse_ps", density, "xla"),
            (f"omnireduce[d={density}]", schemes.omnireduce_sync,
             dict(n=N, block=16, cap_push=max(8, cap // 8),
                  cap_pull=max(8, cap // 8)),
             "omnireduce", density, "xla"),
            (f"balanced[d={density}]", schemes.balanced_sync,
             dict(n=N, cap_push=cap, cap_pull=cap),
             "balanced", density, "xla"),
        ] + [
            (f"zen[{b},d={density}]", schemes.zen_sync,
             dict(layout=layout, backend=b, interpret=None),
             "zen", density, b)
            for b in BACKENDS
        ]
    for name, fn, kwargs, scheme, density, backend in cases:
        vals = _workers(M, density)
        run = jax.jit(functools.partial(
            schemes.simulate, fn, **kwargs))
        out, stats = run(vals)
        e2e_us = time_fn(run, vals)
        _record(
            results, name, e2e_us,
            stage="e2e", scheme=scheme, density=density, backend=backend,
            sent_words=float(np.asarray(stats.sent_words).mean()),
            overflow=int(np.asarray(stats.overflow).sum()),
        )
        if scheme == "zen":
            # per-stage split (DESIGN.md §11/§14): the local encode prefix
            # in isolation, plus a DIRECT commit probe — encodes are
            # materialized outside the timed function, so commit_us is a
            # measurement, not the old residual e2e - N * encode (whose
            # clamp hid the commit share under encode noise; same fix as
            # CostCalibrator v2).  Lands in the run.py JSON "stages"
            # field instead of being flattened into one wall-clock number.
            enc = jax.jit(functools.partial(
                schemes.zen_encode, layout=kwargs["layout"],
                backend=backend, interpret=None))
            enc_us = time_fn(enc, vals[0])
            encs = jax.block_until_ready(jax.jit(jax.vmap(enc))(vals))
            commit_run = jax.jit(jax.vmap(functools.partial(
                schemes.zen_commit, axis=schemes.AXIS,
                layout=kwargs["layout"], backend=backend,
                interpret=None), axis_name=schemes.AXIS))
            commit_us = time_fn(commit_run, encs, vals) / N
            record_stage_times(
                "micro_sync", name, encode_us=enc_us,
                commit_us=commit_us, e2e_us=e2e_us)


def bench_bucketed(results: list, densities=DENSITIES) -> None:
    """Trainer-shaped sync step: monolithic GradSync vs the bucketed
    double-buffered schedule at equal density (the ``bucketed`` series the
    perf trajectory tracks — step time must not exceed monolithic)."""
    from repro.core.zen import SyncConfig

    for density in densities:
        shapes, grads = synthetic_grad_tree(N, density=density)
        arms = {}
        for bb, tag in ((None, "mono"), (BUCKET_BYTES, "bucketed")):
            cfg = SyncConfig(scheme="zen",
                             density_budget=min(0.5, 4 * density),
                             bucket_bytes=bb)
            arms[tag] = (bb,) + build_gradsync_run(cfg, shapes, grads, N)
        # interleaved A/B: both programs sample the same host-noise window
        times = time_ab({t: a[1] for t, a in arms.items()}, grads, rounds=50)
        for tag, (bb, _, stats, plan) in arms.items():
            _record(
                results, f"bucketed[{tag},d={density}]", times[tag],
                stage="bucketed_e2e", scheme="zen", density=density,
                backend="xla",
                bucket_bytes=0 if bb is None else bb,
                n_buckets=len(plan.buckets),
                sent_words=float(
                    np.asarray(stats["sync/sparse_sent_words"]).mean()),
                dense_words=float(
                    np.asarray(stats["sync/dense_words"]).mean()),
                overflow=int(np.asarray(stats["sync/overflow"]).sum()),
            )
        emit(f"micro_sync/bucketed_speedup[d={density}]", 0.0,
             f"mono/bucketed={times['mono'] / times['bucketed']:.2f}x")


HIER_DENSITIES = (0.01, 0.1)   # both modes: the inter-volume bar (§10)
NODE_SIZE = 2                  # N=4 workers -> 2 nodes x 2 devices


def bench_hier(results: list, densities=HIER_DENSITIES) -> None:
    """Two-level CommPlan series (DESIGN.md §10): flat zen vs the
    hierarchical plans over a node-split topology, at matched density.
    The acceptance bar — the two-level plan's wire volume on the INTER
    level must not exceed flat zen's total at d in {0.01, 0.1} — is
    asserted here, so the CI bench gate enforces it on every run; the
    recorded ``inter_words`` are also exact-gated by check_regression."""
    from repro.core import topology as tpg

    topo = tpg.build_topology(N, NODE_SIZE)
    for density in densities:
        vals = _workers(M, density)
        budget = min(0.5, 4 * density)
        lo_flat = schemes.make_zen_layout(M, N, density_budget=budget)
        flat_run = jax.jit(functools.partial(
            schemes.simulate, schemes.zen_sync, layout=lo_flat))
        _, st_flat = flat_run(vals)
        flat_words = float(np.asarray(st_flat.sent_words).mean())

        # per-stage provisioning routed through the shared StageArgs
        # builder: capacity growth across the intra merge and zen layout
        # sizing computed in ONE place (schemes.plan_stage_args), the
        # same code path GradSync uses — not re-derived per harness
        tags = (
            "hier(zen@intra,zen@inter)",
            "hier(zen@intra,agsparse@inter)",
            "hier(dense@intra,dense@inter)",
        )
        best_inter = None
        for tag in tags:
            plan = tpg.parse_plan(tag)
            stage_kw = schemes.plan_stage_args(plan, topo, M,
                                               density_budget=budget)
            run = jax.jit(functools.partial(
                schemes.simulate_hier, topology=topo, plan=plan,
                stage_kw=stage_kw))
            out, st = run(vals)
            assert int(np.asarray(st.overflow).sum()) == 0, (tag, density)
            intra_w = float(np.asarray(st.by_level[0]).mean())
            inter_w = float(np.asarray(st.by_level[1]).mean())
            _record(
                results, f"hier[{tag},d={density}]", time_fn(run, vals),
                stage="hier_e2e", scheme=tag, density=density,
                backend="xla", node_size=NODE_SIZE,
                sent_words=float(np.asarray(st.sent_words).mean()),
                intra_words=intra_w, inter_words=inter_w,
                flat_zen_words=flat_words,
            )
            if tag != "hier(dense@intra,dense@inter)":
                assert inter_w <= flat_words, (
                    f"{tag} moves {inter_w:.0f} words across the slow "
                    f"(inter) links at d={density} — more than flat "
                    f"zen's {flat_words:.0f} total; the hierarchy must "
                    f"RELIEVE the slow links (DESIGN.md §10)")
                best_inter = (inter_w if best_inter is None
                              else min(best_inter, inter_w))
        emit(f"micro_sync/hier_inter_ratio[d={density}]", 0.0,
             f"best_inter/flat_zen={best_inter / flat_words:.3f}")


ENC_N = 8                        # the fused-encode gate's host mesh size
ENC_DENSITIES = (0.01, 0.05)     # smoke keeps 0.01: the gate's bar
ENC_RATIO_BAR = 0.5              # fused <= 0.5x the 3-dispatch at d<=0.01


BAL_DENSITIES = (0.01, 0.1)    # both modes: the skew bar (§12) every run


def bench_balanced(results: list, densities=BAL_DENSITIES) -> None:
    """Balanced (Ok-Topk family) vs agsparse A/B under uniform and
    fully-skewed nonzeros (DESIGN.md §12).  Provisioning is the point:
    balanced's buffers follow the skew-independent balanced bound
    (total/n + one-bin slack) while agsparse must size its allgather
    for the worst worker (nnz_max — the whole total under full skew).
    The acceptance bar asserted here and re-enforced by
    check_regression: the bottleneck worker's wire volume under full
    skew must not exceed agsparse's; the recorded sent_words are
    deterministic and exact-gated (VOLUME_KEYS)."""
    rng = np.random.default_rng(7)
    for density in densities:
        total = int(N * M * density)
        bal_cap = total // N + min(total, N * (M // BALANCED_BINS))
        for arm in ("uniform", "skew"):
            g = np.zeros((N, M), np.float32)
            if arm == "uniform":
                nnz_max = total // N
                for i in range(N):
                    pos = rng.choice(M, size=nnz_max, replace=False)
                    g[i, pos] = rng.standard_normal(nnz_max).astype(np.float32)
            else:
                nnz_max = total
                pos = rng.choice(M, size=total, replace=False)
                g[0, pos] = rng.standard_normal(total).astype(np.float32)
            vals = jnp.asarray(g)
            sent = {}
            for scheme, fn, kw in (
                ("balanced", schemes.balanced_sync,
                 dict(n=N, cap_push=bal_cap, cap_pull=bal_cap)),
                ("agsparse", schemes.agsparse_sync, dict(capacity=nnz_max)),
            ):
                run = jax.jit(functools.partial(schemes.simulate, fn, **kw))
                _, st = run(vals)
                ov = int(np.asarray(st.overflow).sum())
                assert ov == 0, (scheme, arm, density)
                sent[scheme] = float(np.asarray(st.sent_words).max())
                _record(
                    results, f"balanced_ab[{scheme},{arm},d={density}]",
                    time_fn(run, vals),
                    stage="balanced_ab", scheme=scheme, arm=arm,
                    density=density, backend="xla",
                    sent_words=sent[scheme], overflow=ov)
            if arm == "skew":
                assert sent["balanced"] <= sent["agsparse"], (
                    f"balanced moves {sent['balanced']:.0f} words at full "
                    f"skew (d={density}), more than agsparse's "
                    f"{sent['agsparse']:.0f} — the rebalance must win "
                    f"exactly where even-range provisioning degrades "
                    f"(DESIGN.md §12)")
            emit(f"micro_sync/balanced_vs_agsparse[{arm},d={density}]", 0.0,
                 f"balanced/agsparse="
                 f"{sent['balanced'] / sent['agsparse']:.2f}x")


def bench_encode_fused(results: list, densities=ENC_DENSITIES) -> None:
    """Fused single-dispatch encode vs the 3-dispatch chain (DESIGN.md
    §11) on the 8-device host mesh.  Both arms compute the SAME function
    — hash + insertion rounds + extraction + bitmap pack — so bit-exact
    parity is asserted before timing and the wall-time ratio is purely
    the fusion win.  The acceptance bar (fused <= 0.5x unfused at
    d=0.01) is asserted here on every run AND gated pairwise by
    check_regression (_gate_encode_fused); the two arms are recorded as
    a pair from one time_ab noise window, like the bucketed series."""
    from repro.kernels import ops as kops

    for density in densities:
        g = _workers(M, density)[0]
        lo = schemes.make_zen_layout(
            M, ENC_N, density_budget=min(0.5, 4 * density))
        idx = jax.jit(
            lambda x, c=lo.cap_index: compact_indices(x != 0, c)[0])(g)
        seeds = lo.static_seeds()
        fused = jax.jit(lambda i: kops.zen_encode_fused_op(
            i, seeds, ENC_N, lo.r1, lo.r2))
        unfused = jax.jit(lambda i: kops.zen_encode_unfused(
            i, seeds, ENC_N, lo.r1, lo.r2))
        a, b = fused(idx), unfused(idx)
        for field, x, y in zip(("pidx", "occ", "overflow"), a, b):
            assert np.array_equal(np.asarray(x), np.asarray(y)), (
                f"fused encode diverged from the 3-dispatch oracle "
                f"({field}, d={density})")
        times = time_ab({"fused": fused, "unfused": unfused}, idx,
                        rounds=40)
        for arm in ("fused", "unfused"):
            _record(results, f"encode_fused[{arm},d={density}]",
                    times[arm], stage="encode_fused", arm=arm,
                    density=density, backend="pallas", n_workers=ENC_N)
        ratio = times["fused"] / times["unfused"]
        record_stage_times(
            "micro_sync", f"encode_fused[d={density}]",
            fused_us=times["fused"], unfused_us=times["unfused"])
        emit(f"micro_sync/encode_fused_ratio[d={density}]", 0.0,
             f"fused/unfused={ratio:.3f} bar<={ENC_RATIO_BAR} at d<=0.01")
        if density <= 0.01:
            assert ratio <= ENC_RATIO_BAR, (
                f"fused encode is {ratio:.2f}x the 3-dispatch time at "
                f"d={density} on the {ENC_N}-device host mesh — the "
                f"megakernel must at least halve the encode "
                f"(acceptance bar {ENC_RATIO_BAR})")


CMT_RATIO_BAR = 0.5              # fused commit <= 0.5x unfused at d<=0.01


def bench_commit_fused(results: list, densities=ENC_DENSITIES) -> None:
    """Fused commit (push megakernel + pull-decode megakernel, DESIGN.md
    §14) vs the pre-fusion dispatch chain on the 8-worker commit payload.
    Both arms compute the SAME function — server scatter-add +
    mask/compact + value gather + bitmap pack, then the batched pull
    unpack+compact — so bit-exact parity is asserted before timing and
    the wall-time ratio is purely the fusion win.  The acceptance bar
    (fused <= 0.5x unfused at d=0.01) is asserted here on every run AND
    gated pairwise by check_regression (_gate_commit_fused); the two
    arms are recorded as a pair from one time_ab noise window, like the
    encode_fused series."""
    from repro.kernels import ops as kops

    rng = np.random.default_rng(3)
    for density in densities:
        lo = schemes.make_zen_layout(
            M, ENC_N, density_budget=min(0.5, 4 * density))
        cap_pull = lo.r1 + lo.r2
        # post-all_to_all commit input: one pidx row from each of ENC_N
        # peers mapped to server-local positions, EMPTY -> cap_server
        # sentinel (exactly what schemes.zen_commit feeds the kernels)
        C = ENC_N * cap_pull
        lp_np = rng.integers(0, lo.cap_server, size=C).astype(np.int32)
        live = rng.random(C) < min(1.0, M * density / C)
        lp_np[~live] = lo.cap_server
        vals_np = np.where(
            live, rng.standard_normal(C), 0.0).astype(np.float32)
        lp, vals = jnp.asarray(lp_np), jnp.asarray(vals_np)

        def _fused(lp, vals, lo=lo, cap_pull=cap_pull):
            lpos, v, bm, ov = kops.zen_commit_push_fused_op(
                lp, vals, cap_server=lo.cap_server, cap_pull=cap_pull)
            all_bm = jnp.tile(bm[None], (ENC_N, 1))  # stands in for the
            lpos_all = kops.zen_commit_pull_fused_op(  # all_gather result
                all_bm, lo.cap_server, cap_pull)
            return lpos, v, bm, ov, lpos_all

        def _unfused(lp, vals, lo=lo, cap_pull=cap_pull):
            lpos, v, bm, ov = kops.zen_commit_push_unfused(
                lp, vals, cap_server=lo.cap_server, cap_pull=cap_pull)
            all_bm = jnp.tile(bm[None], (ENC_N, 1))
            lpos_all = kops.zen_commit_pull_unfused(
                all_bm, lo.cap_server, cap_pull)
            return lpos, v, bm, ov, lpos_all

        fused, unfused = jax.jit(_fused), jax.jit(_unfused)
        a, b = fused(lp, vals), unfused(lp, vals)
        for field, x, y in zip(("lpos", "vals", "bitmap", "overflow",
                                "pull_lpos"), a, b):
            assert np.array_equal(np.asarray(x), np.asarray(y)), (
                f"fused commit diverged from the dispatch-chain oracle "
                f"({field}, d={density})")
        times = time_ab({"fused": fused, "unfused": unfused}, lp, vals,
                        rounds=40)
        for arm in ("fused", "unfused"):
            _record(results, f"commit_fused[{arm},d={density}]",
                    times[arm], stage="commit_fused", arm=arm,
                    density=density, backend="pallas", n_workers=ENC_N)
        ratio = times["fused"] / times["unfused"]
        record_stage_times(
            "micro_sync", f"commit_fused[d={density}]",
            fused_us=times["fused"], unfused_us=times["unfused"])
        emit(f"micro_sync/commit_fused_ratio[d={density}]", 0.0,
             f"fused/unfused={ratio:.3f} bar<={CMT_RATIO_BAR} at d<=0.01")
        if density <= 0.01:
            assert ratio <= CMT_RATIO_BAR, (
                f"fused commit is {ratio:.2f}x the dispatch-chain time at "
                f"d={density} on the {ENC_N}-worker payload — the "
                f"megakernel must at least halve the commit "
                f"(acceptance bar {CMT_RATIO_BAR})")


COMPRESS_DENSITIES = (0.01, 0.05)  # smoke keeps 0.01: the acceptance bar


def bench_compress(results: list, densities=COMPRESS_DENSITIES) -> None:
    """The induced-sparsity series (DESIGN.md §8): an all-dense gradient
    tree synced (a) as fused dense psum buckets and (b) EF top-k
    compressed under scheme='auto'.  Tracks the wire-volume win and the
    EF step-time cost; the acceptance bar — topk+EF wire volume <= 10% of
    dense at density 0.01 with zen selected by 'auto' — is asserted here,
    so the CI bench gate enforces it on every run."""
    from repro.core import buckets as bkt
    from repro.core.zen import SyncConfig

    shapes, grads = synthetic_grad_tree(
        N, n_dense=64, dense_size=1024, with_table=False)
    total = sum(s.size for s in jax.tree.leaves(shapes))
    for density in densities:
        arms = {}
        cfgs = {
            "dense": SyncConfig(scheme="dense", bucket_bytes=BUCKET_BYTES),
            "topk": SyncConfig(scheme="auto", bucket_bytes=BUCKET_BYTES,
                               compress=f"topk:{density:g}"),
        }
        for tag, cfg in cfgs.items():
            arms[tag] = build_gradsync_run(cfg, shapes, grads, N)
        times = time_ab({t: a[0] for t, a in arms.items()}, grads, rounds=50)
        wire = {}
        for tag, (_, stats, plan) in arms.items():
            sparse_w = float(
                np.asarray(stats["sync/sparse_sent_words"]).mean())
            dense_w = float(np.asarray(stats["sync/dense_words"]).mean())
            wire[tag] = sparse_w + dense_w
            schemes = sorted({b.scheme for b in plan.buckets
                              if b.kind == bkt.DENSE})
            _record(
                results, f"compress[{tag},d={density}]", times[tag],
                stage="compress_e2e", density=density, backend="xla",
                compress="none" if tag == "dense" else f"topk:{density:g}",
                schemes=",".join(schemes),
                sent_words=sparse_w, dense_words=dense_w,
                overflow=int(np.asarray(stats["sync/overflow"]).sum()),
            )
        ratio = wire["topk"] / wire["dense"]
        emit(f"micro_sync/compress_wire_ratio[d={density}]", 0.0,
             f"topk/dense={ratio:.4f} M={total}")
        if density <= 0.01:
            _, _, plan = arms["topk"]
            dense_schemes = {b.scheme for b in plan.buckets
                             if b.compress != "none"}
            assert dense_schemes == {"zen"}, (
                f"'auto' picked {dense_schemes} for topk:{density:g} "
                f"buckets — expected zen")
            assert ratio <= 0.10, (
                f"topk+EF wire volume {ratio:.2%} of dense at density "
                f"{density} — acceptance bar is 10%")


def main(argv=()) -> None:
    ap = argparse.ArgumentParser(prog="benchmarks.micro_sync")
    ap.add_argument("out", nargs="?", default=None,
                    help="output JSON path (default BENCH_sync.json)")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="alias for the positional output path (CI gate)")
    ap.add_argument("--smoke", action="store_true",
                    help="single-density quick pass for the CI bench gate "
                         "(same tensor sizes: timings stay comparable)")
    ap.add_argument("--repeat", type=int, default=2,
                    help="replay the whole suite N times and keep the "
                         "per-entry minimum.  Both the committed baseline "
                         "and the CI smoke run use the default so the "
                         "estimator is identical on both sides of the "
                         "regression gate")
    args = ap.parse_args(list(argv))

    densities = (0.05,) if args.smoke else DENSITIES
    # the compress series keeps d=0.01 in BOTH modes: the <=10%-of-dense
    # acceptance assert must hold on every CI bench-gate run
    compress_densities = (0.01,) if args.smoke else COMPRESS_DENSITIES
    # the encode series keeps d=0.01 in BOTH modes: the fused<=0.5x bar
    # must hold on every CI bench-gate run
    enc_densities = (0.01,) if args.smoke else ENC_DENSITIES
    repeat = args.repeat
    # stages whose A/B entries are judged as within-run ratios: keep each
    # (stage, density) pair from its least-contended replay as a unit, so
    # the recorded ratio always comes from one time_ab noise window
    paired_stages = ("bucketed_e2e", "encode_fused", "commit_fused")
    best: dict[str, dict] = {}
    pair_best: dict[tuple, tuple[float, list]] = {}
    for _ in range(repeat):
        results: list[dict] = []
        bench_stages(results)
        bench_end_to_end(results, densities)
        bench_bucketed(results, densities)
        # hier and balanced keep BOTH densities in smoke mode: the
        # inter-level wire bar and the balanced-vs-agsparse skew bar
        # must hold on every CI bench-gate run
        bench_hier(results)
        bench_balanced(results)
        bench_compress(results, compress_densities)
        bench_encode_fused(results, enc_densities)
        # the commit series keeps d=0.01 in BOTH modes too: the fused
        # commit <=0.5x bar must hold on every CI bench-gate run
        bench_commit_fused(results, enc_densities)
        for r in results:
            if r.get("stage") in paired_stages:
                continue  # merged pairwise below
            if r["name"] not in best or r["us"] < best[r["name"]]["us"]:
                best[r["name"]] = r
        for stage in paired_stages:
            stage_densities = sorted(
                {r["density"] for r in results if r.get("stage") == stage})
            for density in stage_densities:
                pair = [r for r in results if r.get("stage") == stage
                        and r["density"] == density]
                total = sum(r["us"] for r in pair)
                key = (stage, density)
                if key not in pair_best or total < pair_best[key][0]:
                    pair_best[key] = (total, pair)
    results = list(best.values()) + [
        r for _, pair in pair_best.values() for r in pair]
    payload = {
        "bench": "micro_sync",
        "meta": {
            "M": M, "n_workers": N, "densities": list(densities),
            "smoke": bool(args.smoke),
            "bucket_bytes": BUCKET_BYTES,
            "device": str(jax.devices()[0]),
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "note": "pallas timings are interpret-mode off-TPU: a "
                    "correctness trajectory, not kernel speed",
        },
        "results": results,
    }
    out = pathlib.Path(args.json_path or args.out or "BENCH_sync.json")
    out.write_text(json.dumps(payload, indent=1))
    emit("micro_sync/written", 0.0, str(out))


if __name__ == "__main__":
    main(sys.argv[1:])

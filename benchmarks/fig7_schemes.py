"""Fig. 7: numerical comparison of communication schemes (NMT profile),
normalized to dense ring-allreduce, n = 4..128."""
import numpy as np

from benchmarks.common import emit, paper_masks
from repro.core import costmodel as cm


def main() -> None:
    masks = paper_masks("nmt", 16)
    p = cm.profile_from_masks(np.asarray(masks), block=256)
    for n in (4, 8, 16, 32, 64, 128):
        t = cm.normalized_times(p, n)
        emit(f"fig7/n{n}", 0.0,
             " ".join(f"{k}={v:.3f}" for k, v in t.items()))
    t128 = cm.normalized_times(p, 128)
    # headline paper claims at 128 GPUs
    assert t128["balanced_parallelism"] < 1.0, "BP must beat dense at n=128"
    assert t128["agsparse"] > 1.0, "AGsparse worse than dense at n=128"
    emit("fig7/zen_vs_dense_128", 0.0,
         f"reduction={(1 - t128['zen']) * 100:.0f}%")


if __name__ == "__main__":
    main()

"""Fig. 15: Push/Pull imbalance ratio — Sparse PS vs Zen, vs #workers."""
import numpy as np

from benchmarks.common import emit, paper_masks
from repro.core import metrics
from repro.core.hashing import hash_mod
from repro.core.schemes import make_zen_layout

import jax.numpy as jnp


def main() -> None:
    elems = 1 << 20
    for n in (4, 8, 16, 32):
        masks = paper_masks("deepfm", n, elems=elems)
        m = np.asarray(masks)
        # Sparse PS: even contiguous partitions
        push_ps = np.stack([mi.reshape(n, -1).sum(1) for mi in m])
        agg = m.any(0)
        pull_ps = agg.reshape(n, -1).sum(1)
        # Zen: h0 hash partitions
        layout = make_zen_layout(elems, n, density_budget=0.1)
        def p_of(idx):
            return np.asarray(
                hash_mod(jnp.asarray(idx, jnp.int32), layout.seeds[0], n))
        push_zen = np.stack([
            np.bincount(p_of(np.nonzero(mi)[0]), minlength=n) for mi in m])
        pull_zen = np.bincount(p_of(np.nonzero(agg)[0]), minlength=n)

        i_push_ps = float(metrics.imbalance_ratio_push(jnp.asarray(push_ps)))
        i_pull_ps = float(metrics.imbalance_ratio_pull(jnp.asarray(pull_ps)))
        i_push_z = float(metrics.imbalance_ratio_push(jnp.asarray(push_zen)))
        i_pull_z = float(metrics.imbalance_ratio_pull(jnp.asarray(pull_zen)))
        emit(f"fig15/n{n}", 0.0,
             f"ps_push={i_push_ps:.2f} ps_pull={i_pull_ps:.2f} "
             f"zen_push={i_push_z:.3f} zen_pull={i_pull_z:.3f}")
        assert i_push_z < 1.1 and i_pull_z < 1.1   # paper: Zen < 1.1 always
        assert i_push_ps > 2.0                     # PS severely imbalanced


if __name__ == "__main__":
    main()

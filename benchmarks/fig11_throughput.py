"""Figs. 11/12: end-to-end training throughput per scheme.

CPU has no real 25/100Gbps network, so throughput combines:
  * measured per-step COMPUTE time of the reduced model on this host, and
  * modeled COMM time = measured per-scheme wire volume (executable shard_map
    schemes, n=16 simulated workers) / network bandwidth,
for the paper's two testbeds (25Gbps TCP, 100Gbps RDMA).  Speedups over
AllReduce are scale-free.
"""

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import PAPER_MODELS, emit, paper_masks
from repro.core import schemes

N = 16
ELEMS = 1 << 20
NETS = {"25gbps": 25e9 / 8, "100gbps": 100e9 / 8}


def measured_volumes(model: str) -> dict:
    """Per-scheme effective communication volume (words).

    For balanced schemes this is the mean per-worker wire volume; for the
    imbalanced ones (Sparse PS, OmniReduce) the step time is set by the
    BOTTLENECK server, so their volume is scaled by the measured pull
    imbalance ratio (Def. 6) — matching the paper's analysis.
    """
    from repro.core import metrics as M

    # row-granular sparsity: the paper's tensors are embedding tables, so
    # non-zeros cluster in d-wide rows (OmniReduce's 256-blocks ≈ rows)
    ROW = 256
    row_masks = paper_masks(model, N, elems=ELEMS // ROW)
    masks = jnp.repeat(row_masks, ROW, axis=1)
    key = jax.random.PRNGKey(0)
    vals = jax.random.normal(key, (N, ELEMS)) * masks
    nnz = int(np.asarray(masks[0]).sum())
    cap = max(1024, int(nnz * 1.5))
    agg = np.asarray(masks).any(0)
    counts = agg.reshape(N, -1).sum(1)
    imb = float(M.imbalance_ratio_pull(jnp.asarray(counts)))
    out = {}
    _, st = schemes.simulate(schemes.dense_sync, vals)
    out["allreduce"] = float(np.asarray(st.sent_words).mean())
    _, st = schemes.simulate(schemes.agsparse_sync, vals, capacity=cap)
    out["agsparse"] = float(np.asarray(st.sent_words).mean())
    _, st = schemes.simulate(schemes.sparcml_sync, vals, n=N, capacity=cap)
    out["sparcml"] = float(np.asarray(st.sent_words).mean())
    _, st = schemes.simulate(schemes.sparse_ps_sync, vals, n=N,
                             cap_push=cap, cap_pull=cap)
    out["sparse_ps"] = float(np.asarray(st.sent_words).mean()) * imb
    blk = 256
    _, st = schemes.simulate(schemes.omnireduce_sync, vals, n=N, block=blk,
                             cap_push=max(8, 2 * cap // blk),
                             cap_pull=max(8, 2 * cap // blk))
    out["omnireduce"] = float(np.asarray(st.sent_words).mean()) * imb
    layout = schemes.make_zen_layout(ELEMS, N, density_budget=1.6 * nnz / ELEMS)
    _, st = schemes.simulate(schemes.zen_sync, vals, layout=layout)
    out["zen"] = float(np.asarray(st.sent_words).mean())
    return out


def main() -> None:
    # representative compute time per step (reduced qwen2 on this host)
    compute_s = 0.05  # measured separately by fig14; fixed here for ratios
    for model in ("lstm", "deepfm"):
        vols = measured_volumes(model)
        scale = PAPER_MODELS[model]["elems"] / ELEMS  # volume scale to full
        for net, bw in NETS.items():
            base = None
            for scheme, words in vols.items():
                comm_s = words * 4 * scale / bw
                thru = 1.0 / (compute_s + comm_s)
                if scheme == "allreduce":
                    base = thru
                emit(f"fig11/{model}_{net}_{scheme}",
                     (compute_s + comm_s) * 1e6,
                     f"rel_throughput={thru / base:.2f}")


if __name__ == "__main__":
    main()

"""Fig. 18: Zen speedup breakdown — Algorithm 1 alone (COO pull) vs
Algorithm 1 + hash bitmap, over AllReduce (measured wire volumes) — plus
the bucketed-schedule breakdown: the same tensors synced through the
double-buffered bucket pipeline (DESIGN.md §7) must move identical wire
volume (bucketing never re-encodes a sparse tensor) while the measured
step time tracks the monolithic path or better."""
import jax
import numpy as np

from benchmarks.common import (
    build_gradsync_run,
    emit,
    paper_masks,
    synthetic_grad_tree,
    time_ab,
)
from repro.core import schemes

N = 16
ELEMS = 1 << 20
N_BUCKET_WORKERS = 4
BUCKET_BYTES = 1 << 16


def bucketed_breakdown(density: float = 0.05) -> None:
    """Monolithic vs bucketed trainer sync: wire-volume parity (the bucket
    planner only fuses *dense* leaves, so sparse traffic is bit-identical)
    and the step-time overlap actually achieved."""
    from repro.core.zen import SyncConfig

    shapes, grads = synthetic_grad_tree(N_BUCKET_WORKERS, density=density)
    runs, vols = {}, {}
    for tag, bb in (("mono", None), ("bucketed", BUCKET_BYTES)):
        run, stats, _ = build_gradsync_run(
            SyncConfig(scheme="zen", density_budget=4 * density,
                       bucket_bytes=bb),
            shapes, grads, N_BUCKET_WORKERS)
        runs[tag] = run
        vols[tag] = (
            float(np.asarray(stats["sync/sparse_sent_words"]).mean()),
            float(np.asarray(stats["sync/dense_words"]).mean()))
    times = time_ab(runs, grads)
    t_m, t_b = times["mono"], times["bucketed"]
    (sw_m, dw_m), (sw_b, dw_b) = vols["mono"], vols["bucketed"]
    assert sw_m == sw_b, (sw_m, sw_b)   # sparse wire volume is invariant
    assert dw_m == dw_b, (dw_m, dw_b)   # fused psums move the same words
    emit("fig18/bucketed", t_b,
         f"mono_us={t_m:.0f} bucketed_us={t_b:.0f} "
         f"speedup={t_m / t_b:.2f}x wire_parity=ok")


def main() -> None:
    for model in ("lstm", "bert"):
        masks = paper_masks(model, N, elems=ELEMS)
        key = jax.random.PRNGKey(0)
        vals = jax.random.normal(key, (N, ELEMS)) * masks
        nnz = int(np.asarray(masks[0]).sum())
        layout = schemes.make_zen_layout(ELEMS, N,
                                         density_budget=1.6 * nnz / ELEMS)
        _, st_d = schemes.simulate(schemes.dense_sync, vals)
        _, st_coo = schemes.simulate(schemes.zen_sync, vals, layout=layout,
                                     use_hash_bitmap=False)
        _, st_bm = schemes.simulate(schemes.zen_sync, vals, layout=layout,
                                    use_hash_bitmap=True)
        d = float(np.asarray(st_d.sent_words).mean())
        coo = float(np.asarray(st_coo.sent_words).mean())
        bm = float(np.asarray(st_bm.sent_words).mean())
        emit(f"fig18/{model}", 0.0,
             f"alg1_coo={d / coo:.2f}x alg1_bitmap={d / bm:.2f}x "
             f"bitmap_extra={(d / bm) / (d / coo) - 1:+.1%}")
    bucketed_breakdown()


if __name__ == "__main__":
    main()

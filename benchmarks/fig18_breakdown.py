"""Fig. 18: Zen speedup breakdown — Algorithm 1 alone (COO pull) vs
Algorithm 1 + hash bitmap, over AllReduce (measured wire volumes)."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import PAPER_MODELS, emit, paper_masks
from repro.core import schemes

N = 16
ELEMS = 1 << 20


def main() -> None:
    for model in ("lstm", "bert"):
        masks = paper_masks(model, N, elems=ELEMS)
        key = jax.random.PRNGKey(0)
        vals = jax.random.normal(key, (N, ELEMS)) * masks
        nnz = int(np.asarray(masks[0]).sum())
        layout = schemes.make_zen_layout(ELEMS, N,
                                         density_budget=1.6 * nnz / ELEMS)
        _, st_d = schemes.simulate(schemes.dense_sync, vals)
        _, st_coo = schemes.simulate(schemes.zen_sync, vals, layout=layout,
                                     use_hash_bitmap=False)
        _, st_bm = schemes.simulate(schemes.zen_sync, vals, layout=layout,
                                    use_hash_bitmap=True)
        d = float(np.asarray(st_d.sent_words).mean())
        coo = float(np.asarray(st_coo.sent_words).mean())
        bm = float(np.asarray(st_bm.sent_words).mean())
        emit(f"fig18/{model}", 0.0,
             f"alg1_coo={d / coo:.2f}x alg1_bitmap={d / bm:.2f}x "
             f"bitmap_extra={(d / bm) / (d / coo) - 1:+.1%}")


if __name__ == "__main__":
    main()

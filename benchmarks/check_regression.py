"""CI bench-regression gate: diff a fresh ``micro_sync`` run against the
committed ``BENCH_sync.json`` baseline.

Compares per-entry timings by result name with a relative tolerance
(default ±30%, override with ``--tolerance`` or ``BENCH_TOLERANCE``),
after normalizing for host speed: the run's **median new/baseline ratio**
is taken as the machine-speed scale (a CI runner is not the laptop that
committed the baseline), and each entry is judged against that scale:

* an entry slower than ``scale * (1 + tol)`` is a regression and fails
  the gate (exit 1);
* an entry faster than ``scale * (1 - tol)`` is reported as an
  improvement — a hint to refresh the committed baseline, never a failure;
* entries present on only one side are reported and skipped (smoke runs
  carry a density subset of the full baseline);
* entries whose baseline time is below ``--min-us`` (default 30000) are
  gated only against a loose 2x bound: entries in the 2-30ms band were
  measured swinging up to ~1.8x across processes on an idle host (6-run
  spread of sparcml/sparse_ps/bucketed; the floor was chosen as the
  tightest value with zero false failures over all ordered pairs of
  those runs), so the ±30% tolerance would be pure jitter there — but a
  genuine 3x stage blow-up (the regression the fast path exists to
  prevent) still fails; below 0.5ms (``JITTER_US``, observed swinging
  >3x) entries are reported only;
* because gating is relative to the scale, a perfectly *uniform*
  slowdown of every entry recalibrates the scale and passes — that is
  the price of a baseline that must survive host changes; the absolute
  trajectory stays visible in the uploaded artifacts;
* ``bucketed_e2e`` entries are gated on the within-run bucketed/mono
  **ratio** instead of wall time — the overlap win is a paired A/B
  measurement, so judging it cross-run would re-import exactly the host
  drift the pairing removes;
* ``encode_fused`` entries are likewise gated on the fresh run's
  within-run fused/unfused ratio, against the ABSOLUTE acceptance bar
  (fused <= 0.5x the 3-dispatch encode at density <= 0.01, DESIGN.md
  §11) rather than the baseline's ratio — the bar is the PR's
  contract, not a trajectory;
* ``commit_fused`` entries carry the commit-side counterpart of that
  bar: fused commit (push megakernel + pull-decode megakernel) <= 0.5x
  the pre-fusion dispatch chain at density <= 0.01 (DESIGN.md §14),
  judged on the fresh run's paired ratio;
* ``balanced_ab`` skew entries are gated absolutely on the fresh run's
  deterministic wire volumes: balanced's bottleneck worker must not
  out-ship agsparse's under full skew (DESIGN.md §12).

Only wall-time is gated with a tolerance.  Wire volumes (``sent_words``
and friends) are deterministic, so any drift there is compared exactly
and also fails — a silent traffic increase is a correctness bug, not
noise.

Run::

    PYTHONPATH=src python -m benchmarks.check_regression \
        BENCH_sync.json BENCH_new.json [--tolerance 0.30]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

VOLUME_KEYS = ("sent_words", "dense_words", "overflow", "intra_words", "inter_words")
JITTER_US = 500.0  # below this, wall time on shared hosts is pure jitter
ENCODE_FUSED_BAR = 0.5  # fused <= 0.5x the 3-dispatch encode at d<=0.01
COMMIT_FUSED_BAR = 0.5  # fused commit <= 0.5x the dispatch chain at d<=0.01


def _index(payload: dict) -> dict:
    return {r["name"]: r for r in payload.get("results", [])}


def _median(xs: list) -> float:
    xs = sorted(xs)
    mid = len(xs) // 2
    if len(xs) % 2:
        return xs[mid]
    return (xs[mid - 1] + xs[mid]) / 2


def _bucketed_ratio(entries: dict) -> dict:
    """Per-density bucketed/mono step-time ratio of a run's A/B series."""
    pairs: dict = {}
    for r in entries.values():
        if r.get("stage") != "bucketed_e2e":
            continue
        density = r.get("density")
        arm = "bucketed" if r.get("bucket_bytes") else "mono"
        pairs.setdefault(density, {})[arm] = r["us"]
    out = {}
    for density, arms in pairs.items():
        if "mono" in arms and "bucketed" in arms and arms["mono"] > 0:
            out[density] = arms["bucketed"] / arms["mono"]
    return out


def _gate_bucketed_pairs(base: dict, new: dict, tolerance: float) -> list:
    """The overlap win is a paired within-run measurement; judge the new
    run's bucketed/mono ratio against the baseline's, not wall times."""
    b_ratio, n_ratio = _bucketed_ratio(base), _bucketed_ratio(new)
    out = []
    for density in sorted(set(b_ratio) & set(n_ratio), key=str):
        b_r, n_r = b_ratio[density], n_ratio[density]
        if n_r > b_r * (1 + tolerance):
            out.append(
                f"bucketed/mono[d={density}]: {b_r:.2f} -> {n_r:.2f} "
                f"(overlap win lost)"
            )
    return out


def _gate_encode_fused(new: dict) -> list:
    """Gate the fused-encode win on the fresh run's within-run
    fused/unfused ratio against the absolute acceptance bar (DESIGN.md
    §11): fused must cost at most ``ENCODE_FUSED_BAR`` of the 3-dispatch
    encode at density <= 0.01 on the bench's host mesh.  Judged per run
    (both arms share one time_ab noise window), never cross-run."""
    pairs: dict = {}
    for r in new.values():
        if r.get("stage") != "encode_fused":
            continue
        pairs.setdefault(r.get("density"), {})[r.get("arm")] = r["us"]
    out = []
    for density in sorted(pairs, key=str):
        arms = pairs[density]
        if "fused" not in arms or not arms.get("unfused"):
            continue
        ratio = arms["fused"] / arms["unfused"]
        if density is not None and density <= 0.01 and ratio > ENCODE_FUSED_BAR:
            out.append(
                f"encode fused/unfused[d={density}]: {ratio:.2f} > "
                f"{ENCODE_FUSED_BAR} (fusion win lost)"
            )
    return out


def _gate_commit_fused(new: dict) -> list:
    """The commit-side counterpart of ``_gate_encode_fused`` (DESIGN.md
    §14): the fused commit megakernel pair must cost at most
    ``COMMIT_FUSED_BAR`` of the pre-fusion dispatch chain at density
    <= 0.01.  Judged per run on the paired within-run ratio."""
    pairs: dict = {}
    for r in new.values():
        if r.get("stage") != "commit_fused":
            continue
        pairs.setdefault(r.get("density"), {})[r.get("arm")] = r["us"]
    out = []
    for density in sorted(pairs, key=str):
        arms = pairs[density]
        if "fused" not in arms or not arms.get("unfused"):
            continue
        ratio = arms["fused"] / arms["unfused"]
        if density is not None and density <= 0.01 and ratio > COMMIT_FUSED_BAR:
            out.append(
                f"commit fused/unfused[d={density}]: {ratio:.2f} > "
                f"{COMMIT_FUSED_BAR} (fusion win lost)"
            )
    return out


def _gate_balanced_skew(new: dict) -> list:
    """The balanced scheme's acceptance bar (DESIGN.md §12): under full
    skew (one worker holds every nonzero) the bottleneck worker's wire
    volume must not exceed agsparse's — the regime where even-range
    provisioning degrades to n * nnz_max is exactly where the rebalance
    must win.  Wire volumes are deterministic, so this is judged
    absolutely on the fresh run, never cross-run."""
    pairs: dict = {}
    for r in new.values():
        if r.get("stage") != "balanced_ab" or r.get("arm") != "skew":
            continue
        pairs.setdefault(r.get("density"), {})[r.get("scheme")] = \
            r.get("sent_words")
    out = []
    for density in sorted(pairs, key=str):
        arms = pairs[density]
        if not arms.get("agsparse") or arms.get("balanced") is None:
            continue
        if arms["balanced"] > arms["agsparse"]:
            out.append(
                f"balanced/agsparse skew wire[d={density}]: "
                f"{arms['balanced']:.0f} > {arms['agsparse']:.0f} words "
                f"(rebalance win lost)"
            )
    return out


def compare(
    baseline: dict, fresh: dict, tolerance: float, min_us: float = 30000.0
) -> int:
    base, new = _index(baseline), _index(fresh)
    shared = [n for n in new if n in base and base[n]["us"] > 0]
    missing = [n for n in new if n not in base]
    ratios = {n: new[n]["us"] / base[n]["us"] for n in shared}
    # calibrate host speed on the gated (non-jitter) entries only
    big = [r for n, r in ratios.items() if base[n]["us"] >= min_us]
    scale = _median(big or list(ratios.values())) if ratios else 1.0
    regressions: list = []
    improvements: list = []
    volume_drift: list = []
    for name in shared:
        b_us, n_us = base[name]["us"], new[name]["us"]
        ratio = ratios[name]
        rel = ratio / scale
        line = f"{name}: {b_us:.0f}us -> {n_us:.0f}us ({rel:.2f}x vs scale)"
        for key in VOLUME_KEYS:
            if key in base[name] and base[name][key] != new[name].get(key):
                drift = f"{base[name][key]} -> {new[name].get(key)}"
                volume_drift.append(f"{name}.{key}: {drift}")
        if new[name].get("stage") in (
            "bucketed_e2e",
            "encode_fused",
            "commit_fused",
        ):
            continue  # wall time gated pairwise below, not cross-run
        if b_us < JITTER_US:
            # sub-0.5ms: observed swinging >3x on idle hosts; report only
            if rel > 1 + tolerance or rel < 1 - tolerance:
                print(f"  jitter-floor drift (not gated) {line}")
        elif b_us < min_us:
            if rel > 2.0:  # loose bound: catches blow-ups, not jitter
                regressions.append(f"(below-floor, >2x) {line}")
            elif rel > 1 + tolerance or rel < 1 - tolerance:
                print(f"  below-floor drift (within 2x, not gated) {line}")
        elif rel > 1 + tolerance:
            regressions.append(line)
        elif rel < 1 - tolerance:
            improvements.append(line)
    regressions += _gate_bucketed_pairs(base, new, tolerance)
    regressions += _gate_encode_fused(new)
    regressions += _gate_commit_fused(new)
    regressions += _gate_balanced_skew(new)
    tol_pct = f"{tolerance:.0%}"
    print(f"bench gate: {len(shared)} entries compared, tolerance {tol_pct}")
    print(f"  host-speed scale (median new/baseline ratio): {scale:.2f}x")
    if missing:
        print(f"  new-only entries (skipped): {len(missing)}")
    base_only = [n for n in base if n not in new]
    if base_only:
        print(f"  baseline-only entries (coverage lost?): {base_only}")
    for line in improvements:
        print(f"  IMPROVED  {line}")
    for line in regressions:
        print(f"  REGRESSED {line}")
    for line in volume_drift:
        print(f"  VOLUME DRIFT {line}")
    if regressions or volume_drift:
        print("bench gate: FAIL")
        return 1
    print("bench gate: ok")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.check_regression")
    ap.add_argument("baseline", help="committed BENCH_sync.json")
    ap.add_argument("fresh", help="freshly produced micro_sync JSON")
    default_tol = float(os.environ.get("BENCH_TOLERANCE", "0.30"))
    ap.add_argument("--tolerance", type=float, default=default_tol)
    ap.add_argument("--min-us", type=float, default=30000.0)
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    return compare(baseline, fresh, args.tolerance, args.min_us)


if __name__ == "__main__":
    sys.exit(main())

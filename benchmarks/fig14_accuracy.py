"""Fig. 14: model accuracy — Zen is iteration-wise identical to AllReduce
(no information loss); the lossy strawman degrades with smaller memory.

Executable version: train the reduced qwen2 for K steps under (a) dense
psum, (b) Zen, (c) a lossy strawman sync (drops hash-collided rows), and
compare loss trajectories.

Beyond the paper (DESIGN.md §8): the **EF sweep** adds the
accuracy-vs-compression axis for *induced* sparsity.  A 4-worker
heterogeneous least-squares smoke config (large zero-mean per-worker
offsets on a few coordinates, a small shared signal everywhere else —
the canonical top-k cancellation workload) is trained under dense sync,
top-k **with** error feedback, and top-k **without**.  Per-worker top-k
always spends its budget on the offset coordinates, whose mean cancels,
so without EF the shared signal is never transmitted and the loss stalls
~23% above optimum; with EF the residual memory re-sends the dropped
signal and the (tail-averaged) loss lands within 2% of dense.  The
asserts below hold the full sweep to that bar on every bench run; the
CI-resident twin of this gate (same failure modes: residual sign,
cast-subtraction, worker cancellation) is
``tests/test_sparsify.py::test_topk_with_ef_converges_where_plain_topk_stalls``,
which runs in every tier-1 leg.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.zen import GradSync, SyncConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_mesh
from repro.optim.optimizers import OptConfig
from repro.train.build import attach_train, build_program
from repro.train.steps import TrainerConfig

STEPS = 8


def run(scheme: str, budget: float = 0.9, compress: str = "none"):
    cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(),
                              dtype=jnp.float32)
    mesh = make_mesh((1, 1), ("data", "model"))
    prog = build_program(cfg, mesh, TrainerConfig(
        opt=OptConfig(lr=1e-3),
        sync=SyncConfig(scheme=scheme, density_budget=budget,
                        compress=compress,
                        bucket_bytes=1 << 16 if compress != "none"
                        else None)))
    attach_train(prog, seq_len=32, global_batch=4)
    params = prog.init_params(0)
    opt = prog.init_opt(params)
    data = iter(SyntheticLM(cfg, DataConfig(seq_len=32, batch=4)))
    losses, step_t = [], 0.0
    import time
    for _ in range(STEPS):
        b = next(data)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        t0 = time.perf_counter()
        params, opt, m = prog.train_step(params, opt, batch)
        jax.block_until_ready(m["loss"])
        step_t = time.perf_counter() - t0
        losses.append(float(m["loss"]))
    return losses, step_t


# ---------------------------------------------------------------------------
# EF sweep (induced sparsity): the accuracy-vs-compression axis
# ---------------------------------------------------------------------------

EF_WORKERS = 4
EF_DIM = 256
EF_OFFSET_COORDS = 16     # coordinates carrying the cancelling worker skew
EF_STEPS = 150
EF_LR = 0.1


def _ef_problem():
    """Worker targets c_i = mu + v_i: mu is a small shared signal on every
    coordinate, v_i are large zero-mean offsets on the first few — so
    per-worker top-k (k = EF_OFFSET_COORDS) always picks the offsets."""
    mu = jnp.full((EF_DIM,), 0.5)
    pat = jnp.tile(jnp.asarray([1.0, 1.0, -1.0, -1.0])[:, None],
                   (1, EF_OFFSET_COORDS))
    v = jnp.zeros((EF_WORKERS, EF_DIM)).at[:, :EF_OFFSET_COORDS].set(
        4.0 * pat)
    return mu[None] + v  # [W, M]


def _ef_run(compress: str) -> float:
    """Distributed SGD on f_i(x) = ||x - c_i||^2 / 2 (simulated workers,
    the repo's vmap idiom); returns the loss of the tail-averaged iterate
    (constant-step EF limit-cycles; its Cesàro average converges)."""
    c = _ef_problem()
    gs = GradSync(SyncConfig(scheme="dense", compress=compress), [],
                  {"x": jax.ShapeDtypeStruct((EF_DIM,), jnp.float32)},
                  EF_WORKERS, data_axis="data")
    res = gs.init_residual()
    resb = {k: jnp.zeros((EF_WORKERS, *r.shape), r.dtype)
            for k, r in res.items()}

    @jax.jit
    def sync(g, r, t):
        return jax.vmap(lambda gg, rr: gs({"x": gg}, rr, step=t),
                        axis_name="data")(g, r)

    x = jnp.zeros(EF_DIM)
    tail = []
    for t in range(EF_STEPS):
        g = x[None] - c
        if compress == "none":
            synced = {"x": jnp.mean(g, axis=0)[None]}
        else:
            synced, resb, _ = sync(g, resb, jnp.int32(t))
        x = x - EF_LR * synced["x"][0]
        if t >= EF_STEPS // 2:
            tail.append(np.asarray(x))
    xa = np.mean(tail, axis=0)
    return 0.5 * float(np.mean(np.sum((xa[None] - np.asarray(c)) ** 2, -1)))


def ef_sweep() -> None:
    density = EF_OFFSET_COORDS / EF_DIM
    spec = f"topk:{density:g}"
    f_dense = _ef_run("none")
    f_ef = _ef_run(spec)
    f_noef = _ef_run(f"{spec}:noef")
    gap_ef = (f_ef - f_dense) / f_dense
    gap_noef = (f_noef - f_dense) / f_dense
    emit("fig14/ef_dense", 0.0, f"loss={f_dense:.3f}")
    emit("fig14/ef_topk", 0.0, f"loss={f_ef:.3f} gap={gap_ef:+.3%}")
    emit("fig14/ef_topk_noef", 0.0,
         f"loss={f_noef:.3f} gap={gap_noef:+.3%}")
    # the acceptance bar: EF top-k matches dense within 2%; plain top-k
    # does not (the dropped shared signal never reaches the optimizer)
    assert abs(gap_ef) <= 0.02, f"EF top-k gap {gap_ef:+.3%} exceeds 2%"
    assert abs(gap_noef) > 0.02, (
        f"plain top-k gap {gap_noef:+.3%} unexpectedly within 2% — the "
        f"cancellation workload no longer stresses error feedback")


def main() -> None:
    dense, t_dense = run("dense")
    zen, t_zen = run("zen")
    # "strawman": zen with a tiny density budget => capacity overflow drops
    # gradients (information loss), mimicking the lossy single-hash scheme
    lossy, _ = run("zen", budget=0.002)
    emit("fig14/dense_final", t_dense * 1e6, f"loss={dense[-1]:.4f}")
    emit("fig14/zen_final", t_zen * 1e6,
         f"loss={zen[-1]:.4f} max_dev={max(abs(a - b) for a, b in zip(dense, zen)):.2e}")
    emit("fig14/lossy_final", 0.0,
         f"loss={lossy[-1]:.4f} gap={lossy[-1] - dense[-1]:+.4f}")
    # EF-compressed LM training end-to-end (trainer path; informational —
    # the hard accuracy gate is the deterministic ef_sweep below)
    lm_ef, _ = run("auto", compress="topk:0.05")
    emit("fig14/lm_topk_ef", 0.0,
         f"loss={lm_ef[-1]:.4f} gap={lm_ef[-1] - dense[-1]:+.4f}")
    assert max(abs(a - b) for a, b in zip(dense, zen)) < 5e-3
    # the lossy scheme DEVIATES from the dense trajectory (information was
    # lost); over a few steps the deviation can go either way, so we assert
    # deviation, not direction (the paper's long-horizon accuracy drop is
    # about losing signal, which the deviation demonstrates)
    assert max(abs(a - b) for a, b in zip(dense, lossy)) > 1e-3
    ef_sweep()


if __name__ == "__main__":
    main()

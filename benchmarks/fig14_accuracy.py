"""Fig. 14: model accuracy — Zen is iteration-wise identical to AllReduce
(no information loss); the lossy strawman degrades with smaller memory.

Executable version: train the reduced qwen2 for K steps under (a) dense
psum, (b) Zen, (c) a lossy strawman sync (drops hash-collided rows), and
compare loss trajectories.
"""
import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.zen import SyncConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_mesh
from repro.optim.optimizers import OptConfig
from repro.train.build import attach_train, build_program
from repro.train.steps import TrainerConfig

STEPS = 8


def run(scheme: str, budget: float = 0.9):
    cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(),
                              dtype=jnp.float32)
    mesh = make_mesh((1, 1), ("data", "model"))
    prog = build_program(cfg, mesh, TrainerConfig(
        opt=OptConfig(lr=1e-3),
        sync=SyncConfig(scheme=scheme, density_budget=budget)))
    attach_train(prog, seq_len=32, global_batch=4)
    params = prog.init_params(0)
    opt = prog.init_opt(params)
    data = iter(SyntheticLM(cfg, DataConfig(seq_len=32, batch=4)))
    losses, step_t = [], 0.0
    import time
    for _ in range(STEPS):
        b = next(data)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        t0 = time.perf_counter()
        params, opt, m = prog.train_step(params, opt, batch)
        jax.block_until_ready(m["loss"])
        step_t = time.perf_counter() - t0
        losses.append(float(m["loss"]))
    return losses, step_t


def main() -> None:
    dense, t_dense = run("dense")
    zen, t_zen = run("zen")
    # "strawman": zen with a tiny density budget => capacity overflow drops
    # gradients (information loss), mimicking the lossy single-hash scheme
    lossy, _ = run("zen", budget=0.002)
    emit("fig14/dense_final", t_dense * 1e6, f"loss={dense[-1]:.4f}")
    emit("fig14/zen_final", t_zen * 1e6,
         f"loss={zen[-1]:.4f} max_dev={max(abs(a - b) for a, b in zip(dense, zen)):.2e}")
    emit("fig14/lossy_final", 0.0,
         f"loss={lossy[-1]:.4f} gap={lossy[-1] - dense[-1]:+.4f}")
    assert max(abs(a - b) for a, b in zip(dense, zen)) < 5e-3
    # the lossy scheme DEVIATES from the dense trajectory (information was
    # lost); over a few steps the deviation can go either way, so we assert
    # deviation, not direction (the paper's long-horizon accuracy drop is
    # about losing signal, which the deviation demonstrates)
    assert max(abs(a - b) for a, b in zip(dense, lossy)) > 1e-3


if __name__ == "__main__":
    main()

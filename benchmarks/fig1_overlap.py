"""Fig. 1: (a) overlap-ratio distribution across worker pairs;
(b) densification ratio vs number of workers."""
import itertools

import numpy as np

from benchmarks.common import PAPER_MODELS, emit, paper_masks
from repro.core import metrics


def main() -> None:
    for model in PAPER_MODELS:
        masks = paper_masks(model, 16)
        ratios = [float(metrics.overlap_ratio(masks[a], masks[b]))
                  for a, b in itertools.combinations(range(8), 2)]
        emit(f"fig1a/{model}_overlap", 0.0,
             f"mean={np.mean(ratios):.3f} std={np.std(ratios):.3f} "
             f"min={min(ratios):.3f} max={max(ratios):.3f}")
        gammas = {n: float(metrics.densification_ratio(masks[:n]))
                  for n in (2, 4, 8, 16)}
        emit(f"fig1b/{model}_densification", 0.0,
             " ".join(f"g{n}={g:.2f}" for n, g in gammas.items()))
        # C2: gamma grows but stays < n
        for n, g in gammas.items():
            assert 1.0 <= g < n


if __name__ == "__main__":
    main()

"""Fig. 1: (a) overlap-ratio distribution across worker pairs;
(b) densification ratio vs number of workers;
(c) beyond-paper: comm/compute overlap *achieved* by the bucketed
double-buffered sync schedule (DESIGN.md §7) — measured step time of the
bucketed trainer sync against the monolithic one at equal density, not
just the mask-level opportunity the paper plots."""
import itertools

import numpy as np

from benchmarks.common import (
    PAPER_MODELS,
    build_gradsync_run,
    emit,
    paper_masks,
    synthetic_grad_tree,
    time_ab,
)
from repro.core import metrics

N_WORKERS = 4
BUCKET_BYTES = 1 << 16


def overlap_achieved(density: float = 0.05) -> None:
    """Fig. 1c: the schedule's measured win.  The mask statistics above say
    how much wire time *could* hide; this measures how much the emitted
    bucket pipeline actually recovers (on CPU: op-fusion/dispatch savings;
    on TPU: genuine latency hiding by XLA's scheduler)."""
    from repro.core.zen import SyncConfig

    shapes, grads = synthetic_grad_tree(N_WORKERS, density=density)
    run_m, _, plan_m = build_gradsync_run(
        SyncConfig(scheme="zen", density_budget=4 * density,
                   bucket_bytes=None), shapes, grads, N_WORKERS)
    run_b, _, plan_b = build_gradsync_run(
        SyncConfig(scheme="zen", density_budget=4 * density,
                   bucket_bytes=BUCKET_BYTES), shapes, grads, N_WORKERS)
    times = time_ab({"mono": run_m, "bucketed": run_b}, grads)
    t_mono, t_bkt = times["mono"], times["bucketed"]
    achieved = 1.0 - t_bkt / t_mono
    emit("fig1c/bucketed_overlap", t_bkt,
         f"mono_us={t_mono:.0f} bucketed_us={t_bkt:.0f} "
         f"achieved={achieved:+.1%} "
         f"buckets={len(plan_m.buckets)}->{len(plan_b.buckets)}")


def main() -> None:
    for model in PAPER_MODELS:
        masks = paper_masks(model, 16)
        ratios = [float(metrics.overlap_ratio(masks[a], masks[b]))
                  for a, b in itertools.combinations(range(8), 2)]
        emit(f"fig1a/{model}_overlap", 0.0,
             f"mean={np.mean(ratios):.3f} std={np.std(ratios):.3f} "
             f"min={min(ratios):.3f} max={max(ratios):.3f}")
        gammas = {n: float(metrics.densification_ratio(masks[:n]))
                  for n in (2, 4, 8, 16)}
        emit(f"fig1b/{model}_densification", 0.0,
             " ".join(f"g{n}={g:.2f}" for n, g in gammas.items()))
        # C2: gamma grows but stays < n
        for n, g in gammas.items():
            assert 1.0 <= g < n
    overlap_achieved()


if __name__ == "__main__":
    main()

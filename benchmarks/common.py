"""Shared benchmark utilities: timing, CSV emission, and the four paper
workloads (Table 1) as calibrated synthetic sparsity profiles.

The paper's embedding tables are 23–406M gradients; CPU benchmarks use a
SCALE-fraction of each tensor with the same density/skew (documented in the
`scaled_elems` column) — volumes scale linearly, ratios are scale-free.
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics

# Table 1 of the paper: (embedding gradient words, density)
PAPER_MODELS = {
    "lstm": dict(elems=406_000_000 // 4, density=0.0113),
    "deepfm": dict(elems=214_000_000, density=0.0280),
    "nmt": dict(elems=112_000_000 // 4, density=0.0247),
    "bert": dict(elems=23_000_000 // 4, density=0.0106),
}
SCALE_ELEMS = 1 << 20  # benchmark-tensor size (scale factor documented)


def paper_masks(model: str, n_workers: int, seed: int = 0,
                elems: int = SCALE_ELEMS) -> jnp.ndarray:
    d = PAPER_MODELS[model]["density"]
    key = jax.random.PRNGKey(hash((model, seed)) % (2**31))
    return metrics.synth_sparse_masks(key, n_workers, elems, d)


def time_fn(fn: Callable, *args, iters: int = 7, warmup: int = 2,
            reduce: Callable = np.min) -> float:
    """Wall time per call in microseconds (blocks on jax results).

    ``reduce`` defaults to the minimum: the least-contended observation of
    a deterministic computation, and the estimator least distorted by
    noisy neighbors on shared hosts (same reasoning as ``timeit``) — which
    is what the CI bench gate needs to compare runs across machines and
    load conditions."""
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    return float(reduce(ts) * 1e6)


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


# ---------------------------------------------------------------------------
# synthetic trainer-shaped gradient pytree (bucketed-schedule benchmarks)
# ---------------------------------------------------------------------------

def synthetic_grad_tree(
    n_workers: int, *, n_dense: int = 512, dense_size: int = 64,
    rows: int = 1024, d: int = 8, density: float = 0.05, seed: int = 0,
    with_table: bool = True,
):
    """A model-shaped gradient pytree: one row-sparse embedding table plus
    ``n_dense`` small dense leaves (biases, norms, router weights — the
    long tail that dominates a transformer's *leaf count* while its FLOPs
    live elsewhere).  This is the regime gradient bucketing was invented
    for: per-leaf sync pays a fixed dispatch/collective cost per tiny
    tensor, fused buckets pay it once per ``bucket_bytes``.

    ``with_table=False`` drops the embedding table — the all-dense tree
    the EF compression series uses, where every byte on the wire comes
    from *induced* sparsity.

    Returns (abstract shapes for GradSync, per-worker grads [n, ...])."""
    key = jax.random.PRNGKey(seed)
    kt, km, kd = jax.random.split(key, 3)
    shapes = {
        "layers": {
            f"w{i:02d}": jax.ShapeDtypeStruct((dense_size,), jnp.float32)
            for i in range(n_dense)
        },
    }
    grads = {
        "layers": {
            f"w{i:02d}": jax.random.normal(
                jax.random.fold_in(kd, i), (n_workers, dense_size))
            for i in range(n_dense)
        },
    }
    if with_table:
        shapes["embed"] = {
            "table": jax.ShapeDtypeStruct((rows, d), jnp.float32)}
        mask = metrics.synth_sparse_masks(km, n_workers, rows, density)
        grads["embed"] = {
            "table": jax.random.normal(kt, (n_workers, rows, d))
            * mask[..., None]}
    return shapes, grads


def build_gradsync_run(sync_cfg, shapes, grads, n_workers: int):
    """Jit one vmapped GradSync step; returns (run fn, stats, plan).

    With EF compression configured, every timed call replays the t=0 EF
    step (zero residual, step=0) so the timed function still takes only
    the gradient tree.  Top-k is shape-static, so step timing and wire
    volume match steady state; a series that needs steady-state
    *density* (threshold compression) must thread the residual instead
    of reusing this helper."""
    from repro.core.zen import GradSync

    gs = GradSync(sync_cfg, ["embed/table"], shapes, n_workers,
                  data_axis="data")
    if gs.has_compression:
        res0 = {k: jnp.tile(v[None], (n_workers,) + (1,) * v.ndim)
                for k, v in gs.init_residual().items()}

        def run_once(g):
            synced, _, stats = jax.vmap(
                lambda gg, rr: gs(gg, rr, step=jnp.int32(0)),
                axis_name="data")(g, res0)
            return synced, stats

        run = jax.jit(run_once)
    else:
        run = jax.jit(lambda g: jax.vmap(gs, axis_name="data")(g))
    _, stats = jax.block_until_ready(run(grads))
    return run, stats, gs.plan


# ---------------------------------------------------------------------------
# per-stage timing registry (benchmarks/run.py "stages" report field)
# ---------------------------------------------------------------------------

# module name -> {series label -> {stage name -> us}}.  Bench modules fill
# this via record_stage_times; run.py attaches it to each module's JSON
# entry so encode vs commit time survives into BENCH_sync.json instead of
# being flattened into one wall-clock number.
STAGE_TIMES: dict[str, dict] = {}


def record_stage_times(module: str, series: str, **stages: float) -> None:
    """Record per-stage wall times (us) for one benchmark series.

    ``stages`` are stage-name -> microseconds pairs (e.g. ``encode_us=...,
    commit_us=...``).  Repeated calls for the same (module, series) keep
    the minimum per stage — matching ``time_fn``'s least-contended-
    observation estimator across --repeat rounds."""
    mod = STAGE_TIMES.setdefault(module, {})
    prev = mod.setdefault(series, {})
    for name, us in stages.items():
        val = float(us)
        prev[name] = min(prev[name], val) if name in prev else val


def time_ab(fns: dict, *args, rounds: int = 30, warmup: int = 3) -> dict:
    """Interleaved A/B timing on a noisy shared host.

    Alternates single calls of each candidate within every round so all
    arms sample the same drift window, then reports the per-arm median
    over rounds.  Because the samples are paired, medians stay comparable
    even when the host load shifts mid-run.  Returns ``{name: us}``."""
    for fn in fns.values():
        for _ in range(warmup):
            jax.block_until_ready(fn(*args))
    samples: dict = {name: [] for name in fns}
    for _ in range(rounds):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            samples[name].append(time.perf_counter() - t0)
    return {name: float(np.median(ts)) * 1e6 for name, ts in samples.items()}

"""Shared benchmark utilities: timing, CSV emission, and the four paper
workloads (Table 1) as calibrated synthetic sparsity profiles.

The paper's embedding tables are 23–406M gradients; CPU benchmarks use a
SCALE-fraction of each tensor with the same density/skew (documented in the
`scaled_elems` column) — volumes scale linearly, ratios are scale-free.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics

# Table 1 of the paper: (embedding gradient words, density)
PAPER_MODELS = {
    "lstm": dict(elems=406_000_000 // 4, density=0.0113),
    "deepfm": dict(elems=214_000_000, density=0.0280),
    "nmt": dict(elems=112_000_000 // 4, density=0.0247),
    "bert": dict(elems=23_000_000 // 4, density=0.0106),
}
SCALE_ELEMS = 1 << 20  # benchmark-tensor size (scale factor documented)


def paper_masks(model: str, n_workers: int, seed: int = 0,
                elems: int = SCALE_ELEMS) -> jnp.ndarray:
    d = PAPER_MODELS[model]["density"]
    key = jax.random.PRNGKey(hash((model, seed)) % (2**31))
    return metrics.synth_sparse_masks(key, n_workers, elems, d)


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time per call in microseconds (blocks on jax results)."""
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)

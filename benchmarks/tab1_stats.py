"""Table 1: model workloads and their gradient sparsity statistics."""

from benchmarks.common import PAPER_MODELS, SCALE_ELEMS, emit, paper_masks
from repro.core import metrics


def main() -> None:
    for model, spec in PAPER_MODELS.items():
        masks = paper_masks(model, 1)
        d = float(metrics.density(masks[0]))
        emit(f"tab1/{model}_density", 0.0,
             f"density={d:.4f} target={spec['density']:.4f} "
             f"full_elems={spec['elems']} scaled_elems={SCALE_ELEMS}")
        assert abs(d - spec["density"]) / spec["density"] < 0.1


if __name__ == "__main__":
    main()

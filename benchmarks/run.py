"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (benchmarks.common.emit).
Run: ``PYTHONPATH=src python -m benchmarks.run [module ...]``
"""
import importlib
import sys
import time
import traceback

MODULES = [
    "tab1_stats",      # Table 1
    "fig1_overlap",    # Fig. 1 (a/b)
    "fig2_skewness",   # Fig. 2
    "fig7_schemes",    # Fig. 7
    "fig8_strawman",   # Fig. 8
    "fig11_throughput",  # Figs. 11/12
    "fig13_comm",      # Fig. 13
    "fig14_accuracy",  # Fig. 14
    "fig15_imbalance",  # Fig. 15
    "fig16_params",    # Fig. 16
    "fig17_bitmap",    # Fig. 17
    "fig18_breakdown",  # Fig. 18
    "roofline",        # §Roofline (reads results/dryrun)
]


def main() -> None:
    only = sys.argv[1:]
    failures = []
    print("name,us_per_call,derived")
    for name in (only or MODULES):
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            mod.main()
            print(f"bench/{name},{(time.time()-t0)*1e6:.0f},ok", flush=True)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            print(f"bench/{name},{(time.time()-t0)*1e6:.0f},"
                  f"FAILED {type(e).__name__}", flush=True)
            failures.append(name)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()

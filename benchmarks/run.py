"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (benchmarks.common.emit).
Run: ``PYTHONPATH=src python -m benchmarks.run [--json PATH] [module ...]``

``--json PATH`` additionally writes a machine-readable report
(per-module wall time and status) for the perf trajectory / CI.

Exit code: non-zero iff any sub-benchmark failed — including one that
calls ``sys.exit`` internally — so the CI bench gate can trust it.  The
JSON report is written even when modules fail.

Modules that call ``benchmarks.common.record_stage_times`` get a
``stages`` field in their report entry — per-series stage timings (e.g.
encode vs commit, DESIGN.md §11) instead of one flattened wall-clock
number per module.
"""
import argparse
import importlib
import inspect
import json
import sys
import time
import traceback

MODULES = [
    "tab1_stats",      # Table 1
    "fig1_overlap",    # Fig. 1 (a/b/c)
    "fig2_skewness",   # Fig. 2
    "fig7_schemes",    # Fig. 7
    "fig8_strawman",   # Fig. 8
    "fig11_throughput",  # Figs. 11/12
    "fig13_comm",      # Fig. 13
    "fig14_accuracy",  # Fig. 14
    "fig15_imbalance",  # Fig. 15
    "fig16_params",    # Fig. 16
    "fig17_bitmap",    # Fig. 17
    "fig18_breakdown",  # Fig. 18
    "micro_sync",      # zen_sync per-stage + e2e + bucketed perf trajectory
    "roofline",        # §Roofline (reads results/dryrun)
]


# per-module argv for ``--smoke`` (the CI bench-gate pass): modules whose
# main() takes argv get their quick single-density configuration; all
# others already run in seconds and need no smoke variant
SMOKE_ARGS = {
    "micro_sync": ("--smoke", "--json", "BENCH_smoke.json"),
}


def _run_module(name: str, smoke: bool = False) -> str:
    """Import + run one benchmark; returns 'ok' or 'FAILED <reason>'.

    ``SystemExit`` is treated like any other failure (recorded, the loop
    continues, the harness still exits non-zero) instead of aborting the
    remaining modules mid-run with whatever code the module chose."""
    try:
        mod = importlib.import_module(f"benchmarks.{name}")
        argv = SMOKE_ARGS.get(name) if smoke else None
        if argv is not None and inspect.signature(mod.main).parameters:
            mod.main(argv)
        else:
            mod.main()
        return "ok"
    except SystemExit as e:
        if not e.code:  # sys.exit(0)/sys.exit(None): a successful exit
            return "ok"
        return f"FAILED SystemExit({e.code})"
    except Exception as e:  # noqa: BLE001
        traceback.print_exc()
        return f"FAILED {type(e).__name__}"


def main() -> None:
    ap = argparse.ArgumentParser(prog="benchmarks.run")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write a JSON report of module timings/status")
    ap.add_argument("--smoke", action="store_true",
                    help="CI bench-gate pass: quick single-density configs "
                         "(micro_sync writes BENCH_smoke.json for "
                         "benchmarks.check_regression)")
    ap.add_argument("modules", nargs="*",
                    help=f"subset to run (default: all of {MODULES})")
    args = ap.parse_args()
    report = []
    failures = []
    print("name,us_per_call,derived")
    for name in (args.modules or MODULES):
        t0 = time.perf_counter()
        status = _run_module(name, smoke=args.smoke)
        if status != "ok":
            failures.append(name)
        us = (time.perf_counter() - t0) * 1e6
        print(f"bench/{name},{us:.0f},{status}", flush=True)
        entry = {"module": name, "us": round(us, 1), "status": status}
        from benchmarks import common
        stages = common.STAGE_TIMES.get(name)
        if stages:
            entry["stages"] = stages
        report.append(entry)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "run", "modules": report,
                       "failures": failures}, f, indent=1)
    if failures:
        print(f"benchmark failures: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()

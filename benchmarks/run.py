"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (benchmarks.common.emit).
Run: ``PYTHONPATH=src python -m benchmarks.run [--json PATH] [module ...]``

``--json PATH`` additionally writes a machine-readable report
(per-module wall time and status) for the perf trajectory / CI.
"""
import argparse
import importlib
import json
import sys
import time
import traceback

MODULES = [
    "tab1_stats",      # Table 1
    "fig1_overlap",    # Fig. 1 (a/b)
    "fig2_skewness",   # Fig. 2
    "fig7_schemes",    # Fig. 7
    "fig8_strawman",   # Fig. 8
    "fig11_throughput",  # Figs. 11/12
    "fig13_comm",      # Fig. 13
    "fig14_accuracy",  # Fig. 14
    "fig15_imbalance",  # Fig. 15
    "fig16_params",    # Fig. 16
    "fig17_bitmap",    # Fig. 17
    "fig18_breakdown",  # Fig. 18
    "micro_sync",      # zen_sync per-stage + e2e perf trajectory
    "roofline",        # §Roofline (reads results/dryrun)
]


def main() -> None:
    ap = argparse.ArgumentParser(prog="benchmarks.run")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write a JSON report of module timings/status")
    ap.add_argument("modules", nargs="*",
                    help=f"subset to run (default: all of {MODULES})")
    args = ap.parse_args()
    report = []
    failures = []
    print("name,us_per_call,derived")
    for name in (args.modules or MODULES):
        t0 = time.perf_counter()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            mod.main()
            status = "ok"
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            status = f"FAILED {type(e).__name__}"
            failures.append(name)
        us = (time.perf_counter() - t0) * 1e6
        print(f"bench/{name},{us:.0f},{status}", flush=True)
        report.append({"module": name, "us": round(us, 1), "status": status})
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "run", "modules": report}, f, indent=1)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()

"""Fig. 17: wire size of sparse formats vs aggregated tensor density
(normalized to the dense tensor; 16 servers)."""
import numpy as np

from benchmarks.common import emit
from repro.core import formats as F
from repro.core.hashing import make_seeds


def main() -> None:
    m = 1 << 18
    n = 16
    seeds = np.asarray(make_seeds(0, 4))
    rng = np.random.default_rng(0)
    dense_bytes = m * 4
    for density in (0.01, 0.05, 0.2, 0.5, 0.8, 0.95):
        mask = rng.uniform(size=m) < density
        nnz = int(mask.sum())
        coo = (4 + 4) * nnz
        blocks = 0
        blk = 256
        nzblocks = int(mask.reshape(-1, blk).any(1).sum())
        blocks = nzblocks * (blk * 4 + 4)
        # per-server bitmaps over the full range (§3.2.1 strawman)
        naive_bitmap = n * (m // 8) + nnz * 4
        hash_bitmap = m // 8 + nnz * 4          # Thm. 3 + values
        emit(f"fig17/d{int(density * 100)}", 0.0,
             f"coo={coo / dense_bytes:.3f} blocks={blocks / dense_bytes:.3f} "
             f"naive_bitmap={naive_bitmap / dense_bytes:.3f} "
             f"hash_bitmap={hash_bitmap / dense_bytes:.3f}")
        if density >= 0.5:
            assert hash_bitmap < coo
        if density <= 0.95:
            assert hash_bitmap < dense_bytes  # paper: saves even at 95%


if __name__ == "__main__":
    main()

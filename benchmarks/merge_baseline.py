"""Refresh the committed bench baseline from a (full, smoke) run pair.

The CI bench gate replays ``micro_sync --smoke`` and diffs it against the
committed ``BENCH_sync.json``.  Entries shared between the two modes must
therefore be *measured by the smoke procedure* in the baseline too: a
full run executes the same case after other densities have warmed
allocator/thread-pool state, which was observed to bias some e2e entries
(sparse_ps) up to 1.4x between modes — far beyond the gate tolerance and
nothing to do with code changes.

This tool overwrites the full run's entries with the smoke run's values
wherever names collide (timings AND volumes — the smoke pass is the
measurement of record for gated entries) and keeps full-only entries
(other densities) for the perf trajectory.  ``make bench-baseline`` runs
the whole refresh.

Run::

    PYTHONPATH=src python -m benchmarks.merge_baseline \
        BENCH_sync.json BENCH_smoke.json
"""
from __future__ import annotations

import argparse
import json


def merge(full: dict, smoke: dict) -> tuple[dict, int]:
    smoke_by_name = {r["name"]: r for r in smoke.get("results", [])}
    merged = []
    replaced = 0
    for r in full.get("results", []):
        if r["name"] in smoke_by_name:
            merged.append(smoke_by_name.pop(r["name"]))
            replaced += 1
        else:
            merged.append(r)
    # smoke-only entries (none today, but a smoke-only series must still
    # be gateable) ride along at the end
    merged.extend(smoke_by_name.values())
    out = dict(full)
    out["results"] = merged
    out["meta"] = dict(full.get("meta", {}),
                       gated_entries_from="micro_sync --smoke")
    return out, replaced


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.merge_baseline")
    ap.add_argument("baseline", help="full-run JSON, updated in place")
    ap.add_argument("smoke", help="smoke-run JSON (measurement of record "
                                  "for shared entries)")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        full = json.load(f)
    with open(args.smoke) as f:
        smoke = json.load(f)
    out, replaced = merge(full, smoke)
    with open(args.baseline, "w") as f:
        json.dump(out, f, indent=1)
    print(f"baseline refreshed: {replaced} gated entries re-measured by "
          f"the smoke procedure, {len(out['results']) - replaced} "
          f"full-only entries kept")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Fig. 2: skewness ratio of non-zero gradient locations vs partitions."""

from benchmarks.common import PAPER_MODELS, emit, paper_masks
from repro.core import metrics


def main() -> None:
    for model in PAPER_MODELS:
        mask = paper_masks(model, 1)[0]
        out = {}
        for n in (8, 16, 32, 64, 128):
            out[n] = float(metrics.skewness_ratio(mask, n))
        emit(f"fig2/{model}_skewness", 0.0,
             " ".join(f"s{n}={v:.1f}" for n, v in out.items()))
        assert out[128] > out[8]  # skew grows with partitions (paper)


if __name__ == "__main__":
    main()

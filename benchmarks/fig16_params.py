"""Fig. 16: Algorithm 1 parameter study — cost vs memory size r1 and vs
rehash count k (wall time of the jitted hierarchical hash on this host;
relative shape is what the paper reports)."""
import jax.numpy as jnp

from benchmarks.common import emit, paper_masks, time_fn
from repro.core import hashing as H


def main() -> None:
    mask = paper_masks("deepfm", 1, elems=1 << 21)[0]
    cap = int(mask.shape[0] * 0.06)
    idx, _ = H.compact_indices(mask, cap)
    nnz = int(jnp.sum(idx != H.EMPTY))
    n = 16
    seeds = H.make_seeds(0, 6)
    # (a) r1 sweep at k=3
    for mult, label in ((1.0, "1x"), (2.0, "2x"), (4.0, "4x")):
        r1 = max(8, int(mult * nnz / n))
        r2 = max(4, r1 // 10)
        us = time_fn(lambda r1=r1, r2=r2: H.hierarchical_hash(
            idx, n=n, r1=r1, r2=r2, k=3, seeds=seeds))
        part = H.hierarchical_hash(idx, n=n, r1=r1, r2=r2, k=3, seeds=seeds)
        serial = int(part.rounds_used[-1])
        emit(f"fig16a/r1_{label}", us,
             f"serial_writes={serial} overflow={int(part.overflow)}")
    # (b) k sweep at r1 = 2x
    r1 = max(8, 2 * nnz // n)
    r2 = max(4, r1 // 10)
    for k in (1, 2, 3, 4):
        us = time_fn(lambda k=k: H.hierarchical_hash(
            idx, n=n, r1=r1, r2=r2, k=k, seeds=seeds))
        part = H.hierarchical_hash(idx, n=n, r1=r1, r2=r2, k=k, seeds=seeds)
        serial = int(part.rounds_used[-1])
        emit(f"fig16b/k{k}", us,
             f"serial_writes={serial} overflow={int(part.overflow)}")
        assert int(part.overflow) == 0 or k < 3


if __name__ == "__main__":
    main()

"""§Roofline: three-term roofline per (arch x shape x mesh) from the
dry-run artifacts in results/dryrun/*.json.

  compute term    = HLO_FLOPs_per_device / peak_FLOPs        (197 TF/s bf16)
  memory term     = HLO_bytes_per_device / HBM_bw            (819 GB/s)
  collective term = collective_bytes_per_device / link_bw    (~50 GB/s/link)

The dry-run compiles the per-device SPMD program (shard_map), so the JSON
numbers are already per-chip; dividing the cluster totals by chips (the
assignment's formulation) is the identical quantity.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

PEAK_FLOPS = 197e12   # bf16 / chip
HBM_BW = 819e9        # B/s
LINK_BW = 50e9        # B/s per ICI link

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun"


def terms(rec: dict) -> dict:
    t_c = rec["flops_per_device"] / PEAK_FLOPS
    t_m = rec["bytes_per_device"] / HBM_BW
    t_n = rec["collective_bytes_total"] / LINK_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_n),
              key=lambda kv: kv[1])
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    # MODEL_FLOPS: 6*N*D for training (fwd+bwd), 2*N*D for inference fwd.
    # Token count derived from the canonical shape table (robust to older
    # dry-run records).
    from repro.configs import INPUT_SHAPES
    spec = INPUT_SHAPES[rec["shape"]]
    factor = 6 if rec["mode"] == "train" else 2
    tokens = spec["global_batch"] * (
        1 if rec["mode"] == "decode" else spec["seq_len"])
    model_flops = factor * rec["n_active_params"] * tokens / chips
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "dominant": dom[0], "bound_s": dom[1],
        "model_flops_per_device": model_flops,
        "useful_fraction": (model_flops / rec["flops_per_device"]
                            if rec["flops_per_device"] > 0 else 0.0),
    }


def load_all(results_dir: Path = RESULTS) -> list[dict]:
    out = []
    for fp in sorted(results_dir.glob("*.json")):
        rec = json.loads(fp.read_text())
        if "error" in rec:
            out.append(rec)
            continue
        rec["roofline"] = terms(rec)
        out.append(rec)
    return out


def main() -> None:
    recs = load_all()
    if not recs:
        emit("roofline/missing", 0.0,
             "run `python -m repro.launch.dryrun --all` first")
        return
    n_err = 0
    for rec in recs:
        tag = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
        if "error" in rec:
            emit(f"roofline/{tag}", 0.0, f"ERROR {rec['error'][:80]}")
            n_err += 1
            continue
        r = rec["roofline"]
        emit(f"roofline/{tag}", r["bound_s"] * 1e6,
             f"dom={r['dominant']} compute={r['compute_s']:.2e}s "
             f"memory={r['memory_s']:.2e}s coll={r['collective_s']:.2e}s "
             f"useful={r['useful_fraction']:.2%}")
    emit("roofline/summary", 0.0,
         f"records={len(recs)} errors={n_err}")


if __name__ == "__main__":
    main()

"""Fig. 8: the strawman's memory-size dilemma — larger memory cuts hash
collisions (information loss) but raises extraction cost."""
import jax.numpy as jnp

from benchmarks.common import emit, paper_masks, time_fn
from repro.core import hashing as H


def main() -> None:
    mask = paper_masks("deepfm", 1)[0]
    idx, _ = H.compact_indices(mask, int(mask.shape[0] * 0.06))
    nnz = int(jnp.sum(idx != H.EMPTY))
    seeds = H.make_seeds(0, 4)
    n = 16
    for mult in (1, 2, 4, 8):
        r = max(8, mult * nnz // n)
        us = time_fn(lambda: H.strawman_hash(idx, n=n, r=r,
                                             seed=int(seeds[0])))
        _, lost = H.strawman_hash(idx, n=n, r=r, seed=int(seeds[0]))
        emit(f"fig8/strawman_mem{mult}x", us,
             f"loss_rate={float(lost) / nnz:.4f} mem_slots={n * r}")
    # Zen's hierarchical hash: no loss at 2x memory
    us = time_fn(lambda: H.hierarchical_hash(
        idx, n=n, r1=2 * nnz // n, r2=max(4, nnz // (5 * n)), k=3,
        seeds=seeds))
    part = H.hierarchical_hash(idx, n=n, r1=2 * nnz // n,
                               r2=max(4, nnz // (5 * n)), k=3, seeds=seeds)
    emit("fig8/zen_hierarchical_2x", us,
         f"loss_rate={float(part.overflow) / nnz:.4f}")
    assert int(part.overflow) == 0


if __name__ == "__main__":
    main()

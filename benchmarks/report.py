"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from
results/dryrun/*.json.  Run after the dry-run:

  PYTHONPATH=src python -m benchmarks.report > /tmp/tables.md
"""
from __future__ import annotations


from benchmarks.roofline import RESULTS, load_all


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def main() -> None:
    recs = [r for r in load_all() if "error" not in r]
    errs = [r for r in load_all() if "error" in r]

    print("### Dry-run table (per-device, from compiled artifacts)\n")
    print("| arch | shape | mesh | compile s | FLOPs/dev | HBM bytes/dev | "
          "collective bytes/dev | peak mem | status |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
              f"{r['compile_s']} | {r['flops_per_device']:.2e} | "
              f"{fmt_bytes(r['bytes_per_device'])} | "
              f"{fmt_bytes(r['collective_bytes_total'])} | "
              f"{fmt_bytes(r['memory']['peak_bytes'])} | OK |")
    for r in errs:
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - | - | - "
              f"| - | ERROR {r['error'][:60]} |")

    print("\n### Roofline table (v5e: 197 TF/s bf16, 819 GB/s HBM, "
          "50 GB/s ICI link)\n")
    print("| arch | shape | mesh | compute s | memory s | collective s | "
          "dominant | MODEL_FLOPS/dev | useful frac | one-line fix |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    fixes = {
        "memory": "fuse attention score/softmax chain (Pallas flash) to"
                  " keep the O(S^2) block in VMEM",
        "compute": "shard the replicated attention heads / raise per-chip"
                   " batch",
        "collective": "reduce-scatter+all-gather (seq-parallel) instead of"
                      " full-activation psum; overlap with compute",
    }
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        t = r["roofline"]
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
              f"{t['compute_s']:.2e} | {t['memory_s']:.2e} | "
              f"{t['collective_s']:.2e} | **{t['dominant']}** | "
              f"{t['model_flops_per_device']:.2e} | "
              f"{t['useful_fraction']:.1%} | {fixes[t['dominant']]} |")

    # optimized-vs-baseline comparison, if the optimized dry-run exists
    opt_dir = RESULTS.parent / "dryrun_optfull"
    if opt_dir.exists():
        opt = {f"{r['arch']}__{r['shape']}": r
               for r in load_all(opt_dir) if "error" not in r}
        base = {f"{r['arch']}__{r['shape']}": r
                for r in recs if r["mesh"] == "16x16"}
        print("\n### §Perf: optimized (pad-heads + fused-attn + MoE-a2a) "
              "vs baseline, 16x16 mesh\n")
        print("| arch | shape | bound s (base) | bound s (opt) | gain | "
              "useful frac base -> opt |")
        print("|---|---|---|---|---|---|")
        for key in sorted(base):
            if key not in opt:
                continue
            b, o = base[key]["roofline"], opt[key]["roofline"]
            gain = b["bound_s"] / o["bound_s"] if o["bound_s"] else 0
            print(f"| {base[key]['arch']} | {base[key]['shape']} | "
                  f"{b['bound_s']:.2e} | {o['bound_s']:.2e} | "
                  f"{gain:.2f}x | {b['useful_fraction']:.1%} -> "
                  f"{o['useful_fraction']:.1%} |")

    # collective breakdown for the most collective-bound combos
    print("\n### Collective breakdown (top-8 by collective share)\n")
    def share(r):
        t = r["roofline"]
        tot = t["compute_s"] + t["memory_s"] + t["collective_s"]
        return t["collective_s"] / tot if tot else 0
    top = sorted(recs, key=share, reverse=True)[:8]
    print("| arch/shape/mesh | share | all-reduce | all-gather | "
          "all-to-all | reduce-scatter | permute |")
    print("|---|---|---|---|---|---|---|")
    for r in top:
        c = r["collectives"]
        print(f"| {r['arch']}/{r['shape']}/{r['mesh']} | {share(r):.1%} | "
              f"{fmt_bytes(c.get('all-reduce', 0))} | "
              f"{fmt_bytes(c.get('all-gather', 0))} | "
              f"{fmt_bytes(c.get('all-to-all', 0))} | "
              f"{fmt_bytes(c.get('reduce-scatter', 0))} | "
              f"{fmt_bytes(c.get('collective-permute', 0))} |")


if __name__ == "__main__":
    main()

"""Fig. 13: communication speedup over AllReduce (16 machines), from the
measured wire volumes of the executable schemes."""
from benchmarks.fig11_throughput import measured_volumes
from benchmarks.common import PAPER_MODELS, emit


def main() -> None:
    for model in PAPER_MODELS:
        vols = measured_volumes(model)
        base = vols["allreduce"]
        derived = " ".join(
            f"{k}={base / v:.2f}x" for k, v in vols.items() if k != "allreduce")
        emit(f"fig13/{model}", 0.0, derived)
        assert vols["zen"] < vols["allreduce"], model
        assert vols["zen"] < vols["omnireduce"], model


if __name__ == "__main__":
    main()

"""Kernel-parity matrix for the fused Zen commit (DESIGN.md §14).

The commit-side counterpart of tests/test_zen_encode_fused.py.  The
contract: the fused commit push (server aggregation + mask/compact +
value gather + bitmap pack in one dispatch) and the fused pull decode
(batched bitmap unpack + row compaction in one dispatch) — megakernel on
TPU, its interpret-mode emulation, and the single-executable XLA
composition the dispatch layer uses off-TPU — are BIT-EXACT against both
oracles:

  * ``zen_commit_push_unfused`` / ``zen_commit_pull_unfused``: the
    pre-fusion dispatch chains (scatter-add kernel + XLA compaction +
    bitmap-pack kernel; bitmap-unpack kernel + XLA compaction), and
  * ``ref.zen_commit_push_ref`` / ``ref.zen_commit_pull_ref``: the
    pure-XLA reference compositions.

The matrix covers density {0.01, 0.1, 1.0} x dtype {f32, bf16} at the
``schemes.zen_sync`` level and overflow-edge buffer layouts at the ops
level (undersized cap_pull: every route must agree on WHICH server rows
survive and HOW MANY overflow).  The collision-free final apply rides on
the disjoint-partition invariant (Thm. 2), property-tested here: the
decoded targets are globally unique, so ``.at[].set`` == ``.at[].add``
into zeros.  CI runs this as part of the ``kernel-parity`` job.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import metrics, schemes
from repro.core.hashing import EMPTY, hash_mod
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def _push_inputs(cap_server: int, C: int, density: float, d=None, seed=0):
    """Synthetic post-all_to_all commit input: server-local positions in
    [0, cap_server) plus sentinel rows (EMPTY positions map to
    cap_server, exactly what ``zen_commit`` feeds the kernel), values
    integer-valued so bf16 sums are exact."""
    rng = np.random.default_rng(seed)
    lp = rng.integers(0, cap_server, size=C).astype(np.int32)
    dead = rng.random(C) >= density
    lp[dead] = cap_server
    shape = (C,) if d is None else (C, d)
    vals = np.round(rng.standard_normal(shape) * 8).astype(np.float32)
    vals[dead] = 0
    return jnp.asarray(lp), jnp.asarray(vals)


def _push_arms(lp, vals, cap_server, cap_pull):
    """All four commit-push routes: fused dispatch (XLA composition
    off-TPU), forced interpret-mode megakernel, pre-fusion chain,
    pure-XLA reference."""
    return {
        "fused": kops.zen_commit_push_fused_op(
            lp, vals, cap_server=cap_server, cap_pull=cap_pull),
        "kernel": kops.zen_commit_push_fused_op(
            lp, vals, cap_server=cap_server, cap_pull=cap_pull,
            force_kernel=True),
        "unfused": kops.zen_commit_push_unfused(
            lp, vals, cap_server=cap_server, cap_pull=cap_pull),
        "ref": kref.zen_commit_push_ref(lp, vals, cap_server, cap_pull),
    }


def _assert_push_parity(arms: dict) -> int:
    lpos0, vals0, bm0, ovf0 = arms["ref"]
    for name in ("fused", "kernel", "unfused"):
        lpos, vals, bm, ovf = arms[name]
        np.testing.assert_array_equal(
            np.asarray(lpos), np.asarray(lpos0), err_msg=f"{name}: lpos")
        np.testing.assert_array_equal(
            np.asarray(vals), np.asarray(vals0), err_msg=f"{name}: vals")
        np.testing.assert_array_equal(
            np.asarray(bm), np.asarray(bm0), err_msg=f"{name}: bitmap")
        assert int(np.asarray(ovf)) == int(np.asarray(ovf0)), \
            f"{name}: overflow"
    return int(np.asarray(ovf0))


# ---------------------------------------------------------------------------
# ops-level matrix: push and pull routes, plus the overflow edge
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", [None, 4], ids=["flat", "rows"])
@pytest.mark.parametrize("cap_server,cap_pull,C,density", [
    (200, 96, 600, 0.05),
    (512, 192, 1024, 0.3),
    (96, 96, 256, 1.0),            # every candidate live, ample memory
])
def test_push_parity_matrix(cap_server, cap_pull, C, density, d):
    lp, vals = _push_inputs(cap_server, C, density, d)
    _assert_push_parity(_push_arms(lp, vals, cap_server, cap_pull))


@pytest.mark.parametrize("cap_server,cap_pull,C,density", [
    (256, 16, 512, 0.5),           # aggregated nnz >> pull capacity
    (100, 8, 300, 1.0),            # unaligned caps, total saturation
])
def test_push_parity_overflow_edge(cap_server, cap_pull, C, density):
    """Undersized cap_pull: the compaction truncates, and every route
    must agree on the surviving prefix AND the overflow count — the edge
    where a fused reimplementation is easiest to get subtly wrong."""
    lp, vals = _push_inputs(cap_server, C, density)
    total = _assert_push_parity(_push_arms(lp, vals, cap_server, cap_pull))
    assert total > 0, "edge config no longer overflows; shrink cap_pull"


@pytest.mark.parametrize("cap_server,cap_pull", [
    (200, 96),
    (1000, 64),                    # bitmap word pad spans several lanes
    (64, 64),
])
def test_pull_parity_matrix(cap_server, cap_pull):
    rng = np.random.default_rng(5)
    n = 4
    W = -(-cap_server // 32)
    words = jnp.asarray(
        rng.integers(0, 1 << 32, size=(n, W), dtype=np.uint64)
        .astype(np.uint32))
    arms = {
        "fused": kops.zen_commit_pull_fused_op(words, cap_server, cap_pull),
        "kernel": kops.zen_commit_pull_fused_op(words, cap_server, cap_pull,
                                                force_kernel=True),
        "unfused": kops.zen_commit_pull_unfused(words, cap_server, cap_pull),
        "ref": kref.zen_commit_pull_ref(words, cap_server, cap_pull),
    }
    base = np.asarray(arms["ref"])
    for name in ("fused", "kernel", "unfused"):
        np.testing.assert_array_equal(np.asarray(arms[name]), base,
                                      err_msg=name)


def test_batched_coo_reduce_backend_parity():
    """The hoisted shared aggregation primitive: pallas (sequential-grid
    RMW kernel) == xla (flattened .at[].add) bit-for-bit, EMPTY and
    out-of-range rows dropped, any leading idx shape."""
    rng = np.random.default_rng(9)
    for d in (None, 3):
        idx = rng.integers(0, 140, size=(4, 64)).astype(np.int32)
        idx[rng.random((4, 64)) < 0.25] = EMPTY
        shape = (4, 64) if d is None else (4, 64, d)
        vals = np.round(rng.standard_normal(shape) * 8).astype(np.float32)
        out_shape = (128,) if d is None else (128, d)
        out = jnp.zeros(out_shape, jnp.float32)
        x = kops.batched_coo_reduce_op(out, jnp.asarray(idx),
                                       jnp.asarray(vals))
        p = kops.batched_coo_reduce_op(out, jnp.asarray(idx),
                                       jnp.asarray(vals), backend="pallas")
        np.testing.assert_array_equal(np.asarray(x), np.asarray(p))
        # indices >= len(out) (but != EMPTY) are dropped, not wrapped
        assert float(np.asarray(x)[:100].sum()) != 0.0


# ---------------------------------------------------------------------------
# Thm. 2: the disjoint-partition invariant behind the collision-free apply
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,n,key", [(1 << 12, 4, 0), (4096, 8, 3),
                                     (3000, 8, 7)])
def test_disjoint_partition_invariant(M, n, key):
    """perm[offsets[p(g)] + local_pos[g]] == g for every global index g:
    servers own non-overlapping ranges of the permutation, so the decode
    targets of distinct live (server, position) pairs never collide —
    the license for ``_scatter_unique``'s combiner-free apply."""
    lo = schemes.make_zen_layout(M, n, density_budget=0.1, key=key)
    g = np.arange(M)
    p = np.asarray(hash_mod(jnp.asarray(g, jnp.int32), lo.seeds[0], n))
    recovered = lo.perm[lo.offsets[p] + lo.local_pos]
    np.testing.assert_array_equal(recovered, g)
    # offsets partition [0, M): ranges are disjoint and cover everything
    assert lo.offsets[0] == 0 and lo.offsets[-1] == M
    assert (np.diff(lo.offsets) >= 0).all()


def test_scatter_unique_equals_scatter_add_on_decode_stream():
    """On a globally-unique target stream (what the zen decode produces),
    the combiner-free set-scatter equals add-into-zeros exactly."""
    rng = np.random.default_rng(11)
    M = 4096
    tgt = rng.choice(M, size=512, replace=False).astype(np.int32)
    tgt[rng.random(512) < 0.2] = EMPTY
    vals = np.round(rng.standard_normal(512) * 256).astype(np.float32) / 256
    out0 = jnp.zeros(M, jnp.float32)
    a = schemes._scatter_add(out0, jnp.asarray(tgt), jnp.asarray(vals))
    s = schemes._scatter_unique(out0, jnp.asarray(tgt), jnp.asarray(vals))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(s))


# ---------------------------------------------------------------------------
# schemes-level matrix: dtype x density, full zen_sync through the fused
# commit — values, wire words and overflow all bit-exact
# ---------------------------------------------------------------------------

def _integer_workers(seed, n, m, density, dtype):
    """Integer-valued worker gradients: sums across workers stay exactly
    representable even in bf16, so bit-exact cross-route comparison is
    meaningful for both wire dtypes."""
    key = jax.random.PRNGKey(seed)
    masks = metrics.synth_sparse_masks(key, n, m, density)
    vals = jnp.round(jax.random.normal(key, (n, m)) * 8)
    return (vals * masks).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("density", [0.01, 0.1, 1.0])
def test_schemes_zen_sync_fused_commit_parity(dtype, density):
    """pallas fused-commit == pallas unfused == xla on the synced values,
    the claimed wire words and the overflow count, at every density and
    in both wire dtypes."""
    n, m = 4, 1 << 12
    vals = _integer_workers(2, n, m, density, dtype)
    lo = schemes.make_zen_layout(m, n,
                                 density_budget=min(1.0, 4 * density))
    base = schemes.simulate(schemes.zen_sync, vals, layout=lo,
                            backend="xla")
    for fc, tag in ((False, "pallas-unfused"), (True, "pallas-fused")):
        out, st = schemes.simulate(schemes.zen_sync, vals, layout=lo,
                                   backend="pallas", fused_commit=fc)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(base[0]), err_msg=tag)
        assert out.dtype == dtype, tag
        np.testing.assert_array_equal(
            np.asarray(st.sent_words), np.asarray(base[1].sent_words),
            err_msg=f"{tag}: sent_words")
        np.testing.assert_array_equal(
            np.asarray(st.overflow), np.asarray(base[1].overflow),
            err_msg=f"{tag}: overflow")


@pytest.mark.parametrize("fused_commit", [False, True],
                         ids=["unfused", "fused"])
def test_schemes_zen_coo_pull_ablation_backend_parity(fused_commit):
    """The COO-pull ablation (use_hash_bitmap=False) through the pallas
    kernel dispatch: previously only the XLA route had tier-1 coverage.
    Both commit routes must match xla bitwise — the ablation changes
    traffic accounting, never values or dispatch correctness."""
    n, m = 4, 2048
    vals = _integer_workers(4, n, m, 0.05, jnp.float32)
    lo = schemes.make_zen_layout(m, n, density_budget=0.2)
    base = schemes.simulate(schemes.zen_sync, vals, layout=lo,
                            backend="xla", use_hash_bitmap=False)
    out, st = schemes.simulate(schemes.zen_sync, vals, layout=lo,
                               backend="pallas", use_hash_bitmap=False,
                               fused_commit=fused_commit)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(base[0]))
    np.testing.assert_array_equal(np.asarray(st.sent_words),
                                  np.asarray(base[1].sent_words))
    np.testing.assert_array_equal(np.asarray(st.overflow),
                                  np.asarray(base[1].overflow))
    # and the psum oracle holds
    np.testing.assert_allclose(np.asarray(out)[0],
                               np.asarray(vals.sum(0)), atol=0)

"""Pallas SSD kernel vs the pure-jnp chunked oracle and a naive recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd import ssd_fwd
from repro.models.ssm import _ssd_chunked


def _inputs(key, B, S, H, hd, N):
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a_log = jax.random.normal(ks[2], (H,)) * 0.3
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.4
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.4
    return xh, dt, a_log, Bm, Cm


@pytest.mark.parametrize("B,S,H,hd,N,chunk", [
    (1, 64, 2, 16, 8, 16),
    (2, 128, 4, 32, 16, 64),
    (1, 96, 1, 64, 32, 32),
])
def test_ssd_kernel_matches_jnp_oracle(B, S, H, hd, N, chunk):
    xh, dt, a_log, Bm, Cm = _inputs(jax.random.PRNGKey(S + H), B, S, H, hd, N)
    # oracle: jnp chunked scan (D=0 skip term)
    y_ref, st_ref = _ssd_chunked(xh, dt, a_log, Bm, Cm,
                                 jnp.zeros((H,)), chunk)
    # kernel expects head-major with dt folded in and per-head dA/B/C
    A = -jnp.exp(a_log)
    dA = (dt * A[None, None, :]).transpose(0, 2, 1).reshape(B * H, S)
    xdt = (xh * dt[..., None]).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    Bh = jnp.broadcast_to(Bm[:, None], (B, H, S, N)).reshape(B * H, S, N)
    Ch = jnp.broadcast_to(Cm[:, None], (B, H, S, N)).reshape(B * H, S, N)
    y_k, st_k = ssd_fwd(xdt, dA, Bh, Ch, chunk=chunk)
    y_k = y_k.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    st_k = st_k.reshape(B, H, hd, N)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_ref),
                               atol=2e-4, rtol=2e-4)


def test_ssd_kernel_matches_naive_recurrence():
    """Step-by-step recurrence oracle (independent of the chunked math)."""
    B, S, H, hd, N = 1, 32, 1, 8, 4
    xh, dt, a_log, Bm, Cm = _inputs(jax.random.PRNGKey(0), B, S, H, hd, N)
    A = -jnp.exp(a_log)
    state = jnp.zeros((hd, N))
    ys = []
    for t in range(S):
        decay = jnp.exp(dt[0, t, 0] * A[0])
        state = state * decay + jnp.outer(xh[0, t, 0] * dt[0, t, 0], Bm[0, t])
        ys.append(state @ Cm[0, t])
    y_naive = jnp.stack(ys)
    dA = (dt * A[None, None, :]).transpose(0, 2, 1).reshape(H, S)
    xdt = (xh * dt[..., None]).transpose(0, 2, 1, 3).reshape(H, S, hd)
    y_k, st_k = ssd_fwd(xdt, dA, Bm.reshape(H, S, N), Cm.reshape(H, S, N),
                        chunk=8)
    np.testing.assert_allclose(np.asarray(y_k[0]), np.asarray(y_naive),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st_k[0]), np.asarray(state),
                               atol=1e-4, rtol=1e-4)

"""Kernel-parity matrix for the fused Zen encode (DESIGN.md §11).

The contract: the fused single-dispatch encode — megakernel on TPU, its
interpret-mode emulation, and the single-executable XLA composition the
dispatch layer uses off-TPU — is BIT-EXACT against both oracles:

  * ``zen_encode_unfused``: the pre-fusion 3-dispatch chain
    (hash_stage kernel + XLA conflict rounds + row_compact kernel +
    bitmap_pack kernel), and
  * ``ref.zen_encode_ref``: the pure-XLA reference composition.

The matrix covers density {0.01, 0.1, 1.0} x bucket sizes including the
serial-memory overflow edge (tiny r1/r2 with ovf > 0 — overflow counting
must agree, not just the surviving indices), the nnz-adaptive lane-budget
branches of the dispatch's ``lax.switch``, and dtype {f32, bf16} at the
``schemes.zen_encode`` level (indices are dtype-free; gathered values are
not).  CI runs this as the ``kernel-parity`` job.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import schemes
from repro.core.hashing import EMPTY, compact_indices, make_seeds
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def _cap(M: int, density: float) -> int:
    """The layout recipe's index capacity: 4x the expected nnz, padded to
    the 128-lane boundary, clamped to the tensor."""
    cap = max(int(M * min(1.0, max(4.0 * density, 8.0 / M))), 8)
    return min(-(-cap // 128) * 128, -(-M // 128) * 128)


def _indices(M: int, density: float, cap: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    mask = rng.random(M) < density
    g = jnp.asarray(np.where(mask, rng.standard_normal(M), 0.0),
                    jnp.float32)
    return compact_indices(g != 0, cap)[0]


def _seeds() -> tuple:
    return tuple(int(s) for s in np.asarray(make_seeds(0, 4)))


def _arms(idx, seeds, n, r1, r2):
    """All four encode routes: fused dispatch, forced interpret-mode
    megakernel, 3-dispatch chain, pure-XLA reference."""
    return {
        "fused": kops.zen_encode_fused_op(idx, seeds, n, r1, r2),
        "kernel": kops.zen_encode_fused_op(idx, seeds, n, r1, r2,
                                           force_kernel=True),
        "unfused": kops.zen_encode_unfused(idx, seeds, n, r1, r2),
        "ref": kref.zen_encode_ref(idx, seeds, n, r1, r2),
    }


def _assert_parity(arms: dict):
    pidx0, occ0, ovf0 = arms["ref"]
    total0 = int(np.sum(np.asarray(ovf0)))
    for name in ("fused", "kernel", "unfused"):
        pidx, occ, ovf = arms[name]
        np.testing.assert_array_equal(
            np.asarray(pidx), np.asarray(pidx0), err_msg=f"{name}: pidx")
        np.testing.assert_array_equal(
            np.asarray(occ), np.asarray(occ0), err_msg=f"{name}: occ")
        assert int(np.sum(np.asarray(ovf))) == total0, f"{name}: overflow"
    return total0


# ---------------------------------------------------------------------------
# ops-level matrix: density x bucket size, plus the overflow edge
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,n,r1,r2,density", [
    (1 << 12, 4, 512, 64, 0.01),
    (1 << 12, 8, 128, 16, 0.1),
    (1 << 14, 8, 192, 24, 0.01),   # the bench gate's operating point
    (1 << 12, 4, 512, 64, 1.0),    # fully dense input, ample memory
])
def test_parity_matrix(M, n, r1, r2, density):
    idx = _indices(M, density, _cap(M, density))
    _assert_parity(_arms(idx, _seeds(), n, r1, r2))


@pytest.mark.parametrize("M,n,r1,r2,density", [
    (512, 2, 16, 4, 1.0),          # dense input into tiny memory
    (1 << 12, 4, 32, 4, 0.5),      # serial region saturates
])
def test_parity_overflow_edge(M, n, r1, r2, density):
    """Undersized r1/r2: every route must agree on WHICH indices survive
    and HOW MANY overflow — the edge where a fused reimplementation is
    easiest to get subtly wrong."""
    idx = _indices(M, density, _cap(M, density))
    total = _assert_parity(_arms(idx, _seeds(), n, r1, r2))
    assert total > 0, "edge config no longer overflows; shrink r1/r2"


def test_fused_dispatch_lane_budget_branches():
    """The off-TPU fused dispatch slices its lane budget from the live
    nnz (lax.switch over {cap, cap/2, cap/4}); every branch and boundary
    must stay bit-exact — trailing EMPTY candidates can never win a slot,
    take a serial rank, or overflow."""
    M, n, r1, r2, cap = 1 << 12, 4, 128, 16, 512
    seeds = _seeds()
    rng = np.random.default_rng(7)
    for nnz in (0, 1, cap // 4 - 1, cap // 4, cap // 4 + 1,
                cap // 2, cap // 2 + 1, cap):
        idx_np = np.full(cap, EMPTY, np.int32)
        idx_np[:nnz] = np.sort(rng.choice(M, nnz, replace=False))
        idx = jnp.asarray(idx_np)
        arms = _arms(idx, seeds, n, r1, r2)
        _assert_parity(arms)


# ---------------------------------------------------------------------------
# schemes-level matrix: dtype x density on ZenEncoded (values included)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("density", [0.01, 0.1, 1.0])
def test_schemes_zen_encode_parity(dtype, density):
    """pallas-fused == pallas-unfused == xla on every ZenEncoded field,
    including the gathered values in both wire dtypes."""
    M, n = 1 << 12, 4
    lo = schemes.make_zen_layout(M, n, density_budget=min(0.5, 4 * density))
    rng = np.random.default_rng(3)
    mask = rng.random(M) < density
    g = jnp.asarray(np.where(mask, rng.standard_normal(M), 0.0),
                    jnp.float32).astype(dtype)
    encs = {
        "pallas_fused": schemes.zen_encode(
            g, layout=lo, backend="pallas", fused=True),
        "pallas_unfused": schemes.zen_encode(
            g, layout=lo, backend="pallas", fused=False),
    }
    base = schemes.zen_encode(g, layout=lo, backend="xla")
    for tag, enc in encs.items():
        np.testing.assert_array_equal(
            np.asarray(enc.pidx), np.asarray(base.pidx),
            err_msg=f"{tag}: pidx")
        np.testing.assert_array_equal(
            np.asarray(enc.pval), np.asarray(base.pval),
            err_msg=f"{tag}: pval")
        assert enc.pval.dtype == dtype, tag
        assert int(enc.overflow) == int(base.overflow), tag

"""Optional-hypothesis shim for tier-1 collection.

``hypothesis`` is a dev-only dependency (requirements-dev.txt).  Importing it
unconditionally made four tier-1 modules fail at *collection* on minimal
images.  Import ``given/settings/st`` from here instead: with hypothesis
installed they are the real thing; without it, property-based tests collect
as skips (via ``pytest.importorskip`` inside the replacement decorator) and
every example-based test in the same module still runs.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def decorate(fn):
            def _skipped():
                # no `reason=` kwarg: pytest only grew it in 8.2 and this
                # shim exists precisely for minimal images (pytest>=7)
                pytest.skip("property test needs hypothesis "
                            "(pip install -r requirements-dev.txt)")
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return decorate

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        """Accepts any strategy construction; the values are never drawn."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()

"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.hashing import EMPTY, make_seeds
from repro.kernels import ops, ref


@pytest.mark.parametrize("C", [64, 1024, 5000])
@pytest.mark.parametrize("n,r1,k", [(16, 512, 3), (4, 128, 1), (256, 4096, 4)])
def test_hash_stage_sweep(C, n, r1, k):
    key = jax.random.PRNGKey(C + n)
    seeds = np.asarray(make_seeds(0, k + 1))
    idx = jax.random.randint(key, (C,), 0, 1 << 30, dtype=jnp.int32)
    idx = jnp.where(jax.random.uniform(key, (C,)) < 0.9, idx, EMPTY)
    p_k, q_k = ops.hash_stage_op(idx, seeds, n=n, r1=r1)
    p_r, q_r = ref.hash_stage_ref(idx, jnp.asarray(seeds), n=n, r1=r1)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))


@settings(deadline=None, max_examples=10)
@given(st.integers(1, 10_000), st.floats(0.0, 1.0), st.integers(0, 99))
def test_bitmap_pack_unpack_property(m, density, seed):
    rng = np.random.default_rng(seed)
    mask = jnp.asarray(rng.uniform(size=m) < density)
    words = ops.bitmap_pack_op(mask)
    pad = (-m) % 32
    want = ref.bitmap_pack_ref(
        jnp.pad(mask.astype(jnp.int32), (0, pad)))
    np.testing.assert_array_equal(np.asarray(words), np.asarray(want))
    back = ops.bitmap_unpack_op(words, m)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(mask))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("C,M,d", [(128, 64, 8), (1000, 256, 128),
                                   (256, 16, 1)])
def test_scatter_add_sweep(C, M, d, dtype):
    key = jax.random.PRNGKey(C * M)
    idx = jax.random.randint(key, (C,), 0, M, dtype=jnp.int32)
    idx = jnp.where(jax.random.uniform(key, (C,)) < 0.15, EMPTY, idx)
    vals = jax.random.normal(key, (C, d), dtype=dtype)
    out = jnp.zeros((M, d), dtype)
    got = ops.coo_scatter_add_op(out, idx, vals)
    want = ref.coo_scatter_add_ref(M, idx, vals)
    tol = 1e-6 if dtype == jnp.float32 else 0.25
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_scatter_add_accumulates_duplicates():
    idx = jnp.asarray([3, 3, 3, EMPTY], jnp.int32)
    vals = jnp.ones((4, 4))
    out = ops.coo_scatter_add_op(jnp.zeros((8, 4)), idx, vals)
    np.testing.assert_allclose(np.asarray(out)[3], 3.0)
    assert float(np.abs(np.asarray(out)).sum()) == pytest.approx(12.0)

"""Sparse formats: roundtrips, wire sizes, Thm. 3 (hash bitmap)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import formats as F
from repro.core.hashing import make_seeds


def _dense(rng, m, density, d=None):
    shape = (m,) if d is None else (m, d)
    x = rng.standard_normal(shape).astype(np.float32)
    mask = rng.uniform(size=m) < density
    return jnp.asarray(x * (mask if d is None else mask[:, None]))


@pytest.mark.parametrize("d", [None, 8])
def test_coo_roundtrip(d):
    rng = np.random.default_rng(0)
    x = _dense(rng, 1000, 0.1, d)
    coo = F.coo_encode(x, 256)
    assert int(coo.overflow) == 0
    y = F.coo_decode(coo, 1000)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=0)


def test_coo_overflow_counted():
    x = jnp.ones(100)
    coo = F.coo_encode(x, 64)
    assert int(coo.overflow) == 36


@settings(deadline=None, max_examples=10)
@given(st.integers(1, 4000), st.integers(0, 100))
def test_bitmap_roundtrip(m, seed):
    rng = np.random.default_rng(seed)
    mask = jnp.asarray(rng.uniform(size=m) < 0.3)
    words = F.bitmap_encode(mask)
    assert words.shape[0] == -(-m // 32)
    got = F.bitmap_decode(words, m)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(mask))


@pytest.mark.parametrize("d", [None, 4])
def test_blocks_roundtrip(d):
    rng = np.random.default_rng(1)
    x = _dense(rng, 1024, 0.05, d)
    blk = F.blocks_encode(x, 16, 64)
    assert int(blk.overflow) == 0
    y = F.blocks_decode(blk, 1024)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=0)


def test_hash_bitmap_roundtrip_and_thm3():
    """Alg. 2 recovers exactly the global non-zero mask, and the TOTAL
    bitmap size is |G|/32 words regardless of n (Thm. 3)."""
    rng = np.random.default_rng(2)
    m = 4096
    seeds = make_seeds(0, 4)
    x = _dense(rng, m, 0.07)
    for n in (2, 8, 32):
        layout = F.make_hash_bitmap_layout(m, n, np.asarray(seeds))
        words = F.hash_bitmap_encode(x, layout)
        # Thm. 3: total words = ceil(m/32), independent of n
        assert words.shape[0] == -(-m // 32)
        mask = F.hash_bitmap_decode(words, layout)
        np.testing.assert_array_equal(np.asarray(mask),
                                      np.asarray(x != 0))


def test_hash_bitmap_per_server_slices():
    """Each server's slice of the permuted bitmap decodes to exactly its
    I_i members' occupancy (the per-server encode/decode of Alg. 2)."""
    rng = np.random.default_rng(3)
    m, n = 2048, 4
    seeds = np.asarray(make_seeds(1, 4))
    layout = F.make_hash_bitmap_layout(m, n, seeds)
    x = _dense(rng, m, 0.1)
    perm = np.asarray(layout.perm)
    offs = np.asarray(layout.offsets)
    permuted_mask = np.asarray(x != 0)[perm]
    for i in range(n):
        seg = permuted_mask[offs[i]: offs[i + 1]]
        # encode segment independently (server-side view)
        pad = (-len(seg)) % 32
        words = F.bitmap_encode(jnp.asarray(np.pad(seg, (0, pad))))
        dec = np.asarray(F.bitmap_decode(words, len(seg)))
        np.testing.assert_array_equal(dec, seg)


def test_wire_sizes_fig17_ordering():
    """Fig. 17: at high density, hash bitmap < COO and < plain-bitmap-per-
    server; at very low density COO wins."""
    rng = np.random.default_rng(4)
    m, n = 1 << 15, 16
    for density, coo_should_win in [(0.005, True), (0.5, False)]:
        x = _dense(rng, m, density)
        nnz = int(np.count_nonzero(np.asarray(x)))
        coo_bytes = nnz * 8
        hash_bitmap_bytes = F.hash_bitmap_wire_bytes(m) + nnz * 4
        naive_bitmap_bytes = n * F.bitmap_wire_bytes(m) // 1 + nnz * 4  # §3.2.1
        assert hash_bitmap_bytes < naive_bitmap_bytes
        if coo_should_win:
            assert coo_bytes < hash_bitmap_bytes
        else:
            assert hash_bitmap_bytes < coo_bytes

"""Hierarchical synchronization (DESIGN.md §10): hier_sync correctness,
GradSync topology invariance, boundary capacity semantics, the schedule's
intra fence, and collective-free axis sizing.

The §10 hard contracts:
  * hierarchical dense == flat dense BITWISE (psum associativity; grads
    here are dyadic so accumulation order cannot perturb bits);
  * hierarchical zen (and every lossless plan) == the psum oracle;
  * the degenerate topology (node_size=1) is bit-identical to a GradSync
    built with no topology at all — plan tags, outputs, and stats;
  * stage capacities grow across the intra boundary (worst-case merged
    density), so a no-overlap worst case stays overflow-free.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metrics, schemes
from repro.core import topology as tp
from repro.core.zen import GradSync, SyncConfig

N = 8
M = 2048


def _dyadic_workers(seed, n, m, density, d=None):
    """Sparse worker grads with dyadic values: any summation order is
    exact, so cross-topology comparisons can be bitwise."""
    key = jax.random.PRNGKey(seed)
    masks = metrics.synth_sparse_masks(key, n, m, density)
    vals = jax.random.normal(key, (n, m) if d is None else (n, m, d))
    vals = jnp.round(vals * 256) / 256
    return vals * (masks if d is None else masks[..., None])


def _hier(vals, plan, topo, stage_kw=None):
    return schemes.simulate_hier(vals, topology=topo, plan=plan,
                                 stage_kw=stage_kw)


@pytest.mark.parametrize("node_size", [2, 4, 8])
def test_hier_dense_bitwise_equals_flat_dense(node_size):
    vals = _dyadic_workers(0, N, M, 0.1)
    topo = tp.build_topology(N, node_size)
    out_h, st = _hier(vals, tp.hier_plan("dense", "dense"), topo)
    out_f, _ = schemes.simulate(schemes.dense_sync, vals)
    np.testing.assert_array_equal(np.asarray(out_h), np.asarray(out_f))
    assert len(st.by_level) == 2
    # wire accounting: ring volume per level (inter level free at ns=8)
    ni, ne = topo.intra.size, topo.inter.size
    want_intra = 2 * (ni - 1) / ni * M
    want_inter = 2 * (ne - 1) / ne * M if ne > 1 else 0.0
    np.testing.assert_allclose(
        np.asarray(st.by_level[0]).reshape(-1)[0], want_intra)
    np.testing.assert_allclose(
        np.asarray(st.by_level[1]).reshape(-1)[0], want_inter)


@pytest.mark.parametrize("plan_tag", [
    "hier(zen@intra,zen@inter)",
    "hier(zen@intra,agsparse@inter)",
    "hier(dense@intra,sparcml@inter)",
    "hier(agsparse@intra,dense@inter)",
    "hier(zen@intra,dense@inter)",       # densify-after-intra
])
@pytest.mark.parametrize("node_size", [2, 4])
def test_hier_plans_match_oracle(plan_tag, node_size):
    vals = _dyadic_workers(1, N, M, 0.05)
    oracle = vals.sum(0)
    topo = tp.build_topology(N, node_size)
    plan = tp.parse_plan(plan_tag)
    # provisioning routed through the shared StageArgs builder — capacity
    # growth across the intra merge and zen layout sizing live in ONE
    # place (schemes.plan_stage_args), not re-derived per test harness
    stage_kw = schemes.plan_stage_args(plan, topo, M, density_budget=0.3)
    out, st = _hier(vals, plan, topo, stage_kw)
    assert int(np.asarray(st.overflow).sum()) == 0
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(oracle)[None].repeat(N, 0),
                               atol=1e-4)


def test_capacity_grows_at_intra_boundary():
    """Worst case for the merge: DISJOINT worker supports, so the
    intra-aggregated tensor is n_intra x denser than any worker.  An
    inter stage provisioned with the per-worker budget would overflow;
    the grown budget must not."""
    node_size = 4
    per = M // (2 * N)     # per-worker density 1/16 -> merged 1/4
    vals = np.zeros((N, M), np.float32)
    for i in range(N):
        vals[i, i * per:(i + 1) * per] = 1.0
    vals = jnp.asarray(vals)
    topo = tp.build_topology(N, node_size)
    budget = per / M * 3            # comfortable PER-WORKER budget
    lo_i = schemes.make_zen_layout(M, node_size, density_budget=budget)
    lo_e_small = schemes.make_zen_layout(M, N // node_size,
                                         density_budget=budget)
    lo_e_grown = schemes.make_zen_layout(
        M, N // node_size, density_budget=min(1.0, budget * node_size))
    plan = tp.parse_plan("hier(zen@intra,zen@inter)")
    _, st_bad = _hier(vals, plan, topo,
                      {0: dict(layout=lo_i), 1: dict(layout=lo_e_small)})
    assert int(np.asarray(st_bad.overflow).sum()) > 0
    out, st_ok = _hier(vals, plan, topo,
                       {0: dict(layout=lo_i), 1: dict(layout=lo_e_grown)})
    assert int(np.asarray(st_ok.overflow).sum()) == 0
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(vals.sum(0)),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# GradSync over topologies
# ---------------------------------------------------------------------------

def _shapes():
    return {
        "embed": {"table": jax.ShapeDtypeStruct((256, 8), jnp.float32)},
        "mlp": {"w1": jax.ShapeDtypeStruct((32, 16), jnp.float32),
                "b": jax.ShapeDtypeStruct((7,), jnp.float32)},
    }


def _grads(shapes, density=0.1):
    import zlib

    from repro.core import buckets as bk
    key = jax.random.PRNGKey(0)

    def leaf(path, s):
        name_seed = zlib.crc32(bk.leaf_path_str(path).encode()) % (1 << 30)
        k = jax.random.fold_in(key, name_seed)
        g = jnp.round(jax.random.normal(k, (N, *s.shape)) * 256) / 256
        if "table" in bk.leaf_path_str(path):
            m = metrics.synth_sparse_masks(k, N, s.shape[0], density)
            g = g * m[..., None]
        return g.astype(s.dtype)

    return jax.tree_util.tree_map_with_path(leaf, shapes)


def _run_gs(gs, grads):
    topo = gs.topology
    if topo.flat:
        return jax.vmap(gs, axis_name=topo.intra.axis)(grads)
    ni, na = topo.inter.size, topo.intra.size
    gr = jax.tree.map(lambda x: x.reshape(ni, na, *x.shape[1:]), grads)
    out, st = jax.vmap(jax.vmap(gs, axis_name=topo.intra.axis),
                       axis_name=topo.inter.axis)(gr)
    out = jax.tree.map(lambda x: x.reshape(ni * na, *x.shape[2:]), out)
    st = jax.tree.map(lambda x: x.reshape(ni * na, *x.shape[2:]), st)
    return out, st


@pytest.mark.parametrize("scheme", ["zen", "dense", "auto"])
@pytest.mark.parametrize("node_size", [1, 2, 4, 8])
def test_gradsync_values_invariant_across_node_sizes(scheme, node_size):
    """Synced values must be BITWISE identical (dyadic grads) for every
    node grouping of the same 8 workers, for every scheme."""
    shapes = _shapes()
    grads = _grads(shapes)
    cfg = SyncConfig(scheme=scheme, density_budget=0.5, bucket_bytes=1024)
    ref = GradSync(cfg, ["embed/table"], shapes, N, data_axis="data")
    out_ref, st_ref = _run_gs(ref, grads)
    topo = tp.build_topology(N, node_size)
    gs = GradSync(cfg, ["embed/table"], shapes, N, data_axis="data",
                  topology=topo)
    out, st = _run_gs(gs, grads)
    for a, b in zip(jax.tree.leaves(out_ref), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(np.asarray(st["sync/overflow"]).sum()) == 0
    if node_size > 1:
        assert "sync/inter_words" in st and "sync/intra_words" in st


def test_degenerate_topology_bit_identical_to_no_topology():
    """node_size=1 IS the pre-refactor stack: same plan tags, same
    outputs, same stats dict, bit for bit."""
    shapes = _shapes()
    grads = _grads(shapes)
    cfg = SyncConfig(scheme="auto", density_budget=0.25, bucket_bytes=512)
    gs0 = GradSync(cfg, ["embed/table"], shapes, N, data_axis="data")
    gs1 = GradSync(cfg, ["embed/table"], shapes, N, data_axis="data",
                   topology=tp.build_topology(N, 1))
    assert [b.scheme for b in gs0.plan.buckets] == \
        [b.scheme for b in gs1.plan.buckets]
    out0, st0 = _run_gs(gs0, grads)
    out1, st1 = _run_gs(gs1, grads)
    for a, b in zip(jax.tree.leaves(out0), jax.tree.leaves(out1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert set(st0) == set(st1)
    for k in st0:
        np.testing.assert_array_equal(np.asarray(st0[k]), np.asarray(st1[k]))


def test_hier_auto_resolves_plan_tags():
    """'auto' on a two-level topology emits CommPlan tags for sparse
    buckets; plain dense buckets keep the 'dense' tag (metric compat)."""
    shapes = _shapes()
    topo = tp.build_topology(N, 4)
    gs = GradSync(SyncConfig(scheme="auto", density_budget=0.01),
                  ["embed/table"], shapes, N, data_axis="data",
                  topology=topo)
    by_name = {b.slots[0].name: b.scheme for b in gs.plan.buckets}
    table_tag = by_name["embed/table"]
    assert table_tag == "dense" or table_tag.startswith("hier("), table_tag
    if table_tag.startswith("hier("):
        tp.parse_plan(table_tag)   # must be grammatical
    assert by_name["mlp/w1"] == "dense"
    # every bucket resolves to an executable two-stage plan
    for line in gs.describe()[1:]:
        assert "plan=[" in line


def test_all_dense_hier_tag_counts_as_dense_words():
    """A plan tag that moves only psum traffic must land in
    sync/dense_words at EVERY node_size — the dense/sparse volume split
    (exact-gated by check_regression) may not change meaning with the
    topology."""
    from repro.core import buckets as bk

    assert bk._all_dense("dense")
    assert bk._all_dense("hier(dense@intra,dense@inter)")
    assert not bk._all_dense("zen")
    assert not bk._all_dense("hier(zen@intra,dense@inter)")
    assert not bk._all_dense("hier(dense@intra,agsparse@inter)")
    assert not bk._all_dense("hier(garbage")

    shapes = {"w": jax.ShapeDtypeStruct((64,), jnp.float32)}
    grads = {"w": jnp.round(
        jax.random.normal(jax.random.PRNGKey(0), (N, 64)) * 256) / 256}
    topo = tp.build_topology(N, 4)
    gs = GradSync(SyncConfig(scheme="dense", bucket_bytes=512),
                  [], shapes, N, data_axis="data", topology=topo)
    _, st = _run_gs(gs, grads)
    assert float(np.asarray(st["sync/sparse_sent_words"]).sum()) == 0.0
    assert float(np.asarray(st["sync/dense_words"]).mean()) > 0.0


def test_gradsync_topology_validation():
    shapes = _shapes()
    with pytest.raises(ValueError, match="workers"):
        GradSync(SyncConfig(), ["embed/table"], shapes, N,
                 data_axis="data", topology=tp.build_topology(4, 2))
    with pytest.raises(ValueError, match="axis"):
        GradSync(SyncConfig(), ["embed/table"], shapes, N,
                 data_axis="data", topology=tp.flat_topology(N, axis="x"))


def test_inter_words_beat_flat_at_low_density():
    """The point of the hierarchy: at low density the slow (inter) links
    carry less than the flat plan pushed across them."""
    vals = _dyadic_workers(3, N, 1 << 14, 0.01)
    layout_f = schemes.make_zen_layout(1 << 14, N, density_budget=0.08)
    _, st_flat = schemes.simulate(schemes.zen_sync, vals, layout=layout_f)
    flat_words = float(np.asarray(st_flat.sent_words).mean())
    topo = tp.build_topology(N, 4)
    lo_i = schemes.make_zen_layout(1 << 14, 4, density_budget=0.08)
    out, st = _hier(vals, tp.parse_plan("hier(zen@intra,agsparse@inter)"),
                    topo, {0: dict(layout=lo_i),
                           1: dict(capacity=1 << 12)})
    inter_words = float(np.asarray(st.by_level[1]).mean())
    assert int(np.asarray(st.overflow).sum()) == 0
    assert inter_words < flat_words, (inter_words, flat_words)
    np.testing.assert_allclose(np.asarray(out[0]),
                               np.asarray(vals.sum(0)), atol=1e-4)


# ---------------------------------------------------------------------------
# schedule fence + axis sizing
# ---------------------------------------------------------------------------

def test_run_schedule_intra_stage_value_identity():
    """The intra hook + its fence are value-identity: the 3-stage
    pipeline returns exactly what calling the stages directly returns."""
    from repro.core.buckets import Bucket, LeafSlot
    from repro.train.schedule import run_schedule

    buckets = [
        Bucket(bid=i, kind="dense_fused", scheme="dense",
               slots=(LeafSlot(f"w{i}", i, (4,), jnp.float32, 0, 4),),
               nbytes=16)
        for i in range(3)
    ]
    payloads = [jnp.arange(4.0) + i for i in range(3)]
    enc_log, intra_log = [], []

    def encode(b, p):
        enc_log.append(b.bid)
        return p * 2

    def intra(b, e):
        intra_log.append(b.bid)
        return e + 1

    def commit(b, e):
        return e * 10, schemes.SyncStats(
            sent_words=jnp.float32(b.bid), overflow=jnp.int32(0))

    outs, stats = run_schedule(buckets, payloads, encode, commit,
                               intra=intra)
    for i in range(3):
        np.testing.assert_allclose(np.asarray(outs[i]),
                                   np.asarray((payloads[i] * 2 + 1) * 10))
    assert enc_log == [0, 1, 2] and intra_log == [0, 1, 2]


def test_axis_size_emits_no_collective():
    """_axis_size must resolve statically: a lowered dense_sync contains
    exactly ONE all-reduce (the gradient psum), not a second one for the
    worker count."""
    from jax.sharding import PartitionSpec as P

    n = jax.device_count()
    mesh = jax.make_mesh((n,), ("data",))
    try:
        sm = jax.shard_map
        kw = dict(check_vma=False)
    except AttributeError:
        from jax.experimental.shard_map import shard_map as sm
        kw = dict(check_rep=False)

    def f(v):
        out, st = schemes.dense_sync(v[0], axis="data")
        return out, st.sent_words

    g = sm(f, mesh=mesh, in_specs=P("data"),
           out_specs=(P(), P()), **kw)
    hlo = jax.jit(g).lower(
        jnp.ones((n, 64))).compile().as_text()
    # zenlint's parsed-HLO counter: async start/done pairs count once
    from repro.analysis import hlo_ir
    n_ar = hlo_ir.count_collectives(hlo_ir.HloModule.parse(hlo),
                                    base="all-reduce")
    assert n_ar == 1, f"expected 1 all-reduce (the psum), found {n_ar}"

"""The trip-count-aware HLO walker against closed-form ground truth."""
import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.launch import hlo_cost


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_trip_count_multiplies_flops():
    def scanned(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 128, 128), jnp.float32)
    r = hlo_cost.analyze(_compile(scanned, x, ws).as_text())
    want = 2 * 128 ** 3 * 7
    assert abs(r["flops"] - want) / want < 0.02


def test_nested_scan():
    def nested(x, ws):
        def outer(c, grp):
            def inner(c2, w):
                return jnp.tanh(c2 @ w), None
            c, _ = lax.scan(inner, c, grp)
            return c, None
        y, _ = lax.scan(outer, x, ws.reshape(3, 4, 128, 128))
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((12, 128, 128), jnp.float32)
    r = hlo_cost.analyze(_compile(nested, x, ws).as_text())
    want = 2 * 128 ** 3 * 12
    assert abs(r["flops"] - want) / want < 0.02


def test_xla_cost_analysis_undercounts_scans():
    """Regression guard for WHY the walker exists."""
    def scanned(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)
    comp = _compile(scanned, x, ws)
    ca = comp.cost_analysis()
    if isinstance(ca, list):  # older jax returns one dict per computation
        ca = ca[0] if ca else {}
    xla = ca.get("flops", 0.0)
    walker = hlo_cost.analyze(comp.as_text())["flops"]
    assert walker > 5 * xla  # XLA counts the body once


def test_collective_wire_factors():
    hlo = """
ENTRY %main (p: f32[64]) -> f32[64] {
  %p = f32[64]{0} parameter(0)
  %ar = f32[64]{0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %ag = f32[64]{0} all-gather(%ar), replica_groups={{0,1}}, dimensions={0}
}
"""
    r = hlo_cost.analyze(hlo)
    # all-reduce: 2*(4-1)/4 * 256B = 384; all-gather: (2-1)/2 * 256B = 128
    assert r["collectives"]["all-reduce"] == pytest.approx(384)
    assert r["collectives"]["all-gather"] == pytest.approx(128)


def test_exclude_bytes_re():
    def f(x):
        with jax.named_scope("flash_fusable"):
            y = x @ x          # standalone dot carrying the scope metadata
        return y @ x

    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    txt = _compile(f, x).as_text()
    full = hlo_cost.analyze(txt)["bytes"]
    excl = hlo_cost.analyze(txt, exclude_bytes_re="flash_fusable")["bytes"]
    assert excl < full

import numpy as np
import pytest

# NOTE: no XLA_FLAGS device-count override here — smoke tests and benches
# must see 1 device (the dry-run sets 512 itself, in its own process).


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-minute integration tests (subprocess meshes)")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)

"""CommPlan IR + α-β topology cost model (DESIGN.md §10).

The contracts under test:
  * plan-tag grammar round-trips; flat tags are bare scheme names
    (bucket identity survives the IR refactor);
  * the DEGENERATE flat topology (α=0, β=1) reproduces the int-``n``
    cost model bit-exactly — times, picks, lower bound;
  * cost-model consistency properties over random profiles:
    ``lower_bound <= min(normalized_times)`` and ``choose_scheme`` /
    ``choose_plan`` are the argmin of the published times (flat AND
    hierarchical);
  * densify-after-intra-aggregation: when the merged density crosses the
    dense/sparse break-even on the inter links, the planner stops
    picking a sparse inter stage.
"""
import math

import pytest
from hypothesis_compat import given, settings, st

from repro.core import costmodel as cm
from repro.core import topology as tp


# ---------------------------------------------------------------------------
# IR
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tag", [
    "zen", "dense", "agsparse", "sparcml",
    "hier(zen@intra,agsparse@inter)",
    "hier(dense@intra,sparcml@inter)",
    "hier(zen@intra,dense@inter)",
])
def test_plan_tag_round_trip(tag):
    assert tp.parse_plan(tag).tag() == tag


@pytest.mark.parametrize("bad", [
    "hier(zen@inter,agsparse@intra)",   # roles out of order
    "hier(zen@intra)",                  # missing inter role
    "zen@intra",                        # role without hier()
    "hier(zen@intra,agsparse@inter",    # unbalanced
])
def test_malformed_plan_tags_rejected(bad):
    with pytest.raises(ValueError):
        tp.parse_plan(bad)


def test_flat_plan_tag_is_bare_scheme():
    assert tp.flat_plan("zen").tag() == "zen"
    assert tp.resolve_plan("zen", tp.flat_topology(8)).stages[0].scheme == "zen"


def test_bare_tag_expands_per_level_on_hier_topology():
    topo = tp.build_topology(8, 4)
    plan = tp.resolve_plan("zen", topo)
    assert [s.scheme for s in plan.stages] == ["zen", "zen"]
    assert plan.tag() == "hier(zen@intra,zen@inter)"


def test_build_topology():
    flat = tp.build_topology(8, 1)
    assert flat.flat and flat.n == 8 and flat.intra.axis == "data"
    assert flat.intra.alpha == 0.0 and flat.intra.beta == 1.0  # degenerate
    hier = tp.build_topology(8, 2)
    assert not hier.flat and hier.n == 8
    assert hier.intra.size == 2 and hier.inter.size == 4
    assert hier.axes == (tp.DP_INTRA, tp.DP_INTER)
    single = tp.build_topology(8, 8)   # one node: size-1 (free) inter level
    assert single.inter.size == 1 and single.n == 8
    with pytest.raises(ValueError, match="does not divide"):
        tp.build_topology(8, 3)


def test_parse_alpha_beta():
    kw = tp.parse_alpha_beta("1,2,3,4")
    assert kw == dict(alpha_intra=1.0, beta_intra=2.0,
                      alpha_inter=3.0, beta_inter=4.0)
    kw2 = tp.parse_alpha_beta("5,6")
    assert kw2["alpha_inter"] == 5.0 and kw2["beta_intra"] == 6.0
    assert tp.parse_alpha_beta(None) == {}
    with pytest.raises(ValueError):
        tp.parse_alpha_beta("1,2,3")
    topo = tp.build_topology(8, 2, alpha_beta="1,2,3,4")
    assert topo.intra.alpha == 1.0 and topo.inter.beta == 4.0


# ---------------------------------------------------------------------------
# random profiles (union-bound-consistent: monotone, concave-ish,
# d(i) <= i * d(1) — what measured densification curves satisfy)
# ---------------------------------------------------------------------------

def _profile(m_log2: int, d1: float, gamma: float, skew: float):
    M = 1 << m_log2
    block = 256

    def d(i):
        return min(1.0, d1 * max(i, 1) ** gamma)

    def s(k):
        return 1.0 + skew * math.log2(max(k, 1))

    return cm.SparsityProfile(
        M=M, d=d, s=s, block=block,
        block_density=lambda i: min(1.0, d(i) * block),
        block_max=lambda i, parts: min(1.0, d(i) * block * s(parts)),
    )


PROFILE_ST = st.tuples(
    st.integers(10, 22),                            # log2 M
    st.floats(1e-4, 0.9),                           # d(1)
    st.floats(0.05, 1.0),                           # densification exponent
    st.floats(0.0, 2.0),                            # skew growth
)


@settings(deadline=None, max_examples=30)
@given(PROFILE_ST, st.sampled_from([2, 4, 8, 16, 64]))
def test_lower_bound_floors_all_schemes_flat(args, n):
    p = _profile(*args)
    t = cm.normalized_times(p, n)
    floor = t.pop("lower_bound")
    assert floor <= min(t.values()) * (1 + 1e-9), (floor, t)


@settings(deadline=None, max_examples=30)
@given(PROFILE_ST, st.sampled_from([2, 4, 8, 16, 64]))
def test_choose_scheme_is_argmin_flat(args, n):
    """choose_scheme == argmin of the published normalized times over its
    decision set {dense, zen} (ties resolve dense)."""
    p = _profile(*args)
    t = cm.normalized_times(p, n)
    want = "zen" if t["zen"] < t["dense"] else "dense"
    assert cm.choose_scheme(p, n) == want
    assert cm.choose_scheme(p, tp.flat_topology(n)) == want


@settings(deadline=None, max_examples=30)
@given(PROFILE_ST, st.sampled_from([(2, 2), (2, 4), (4, 2), (4, 8)]))
def test_lower_bound_floors_all_plans_hier(args, shape):
    n_intra, n_inter = shape
    p = _profile(*args)
    topo = tp.two_level_topology(n_intra, n_inter)
    t = cm.plan_times(p, topo)
    floor = t.pop("lower_bound")
    assert floor <= min(t.values()) * (1 + 1e-9), (floor, t)


@settings(deadline=None, max_examples=30)
@given(PROFILE_ST, st.sampled_from([(2, 2), (2, 4), (4, 2), (4, 8)]))
def test_choose_plan_is_argmin_hier(args, shape):
    n_intra, n_inter = shape
    p = _profile(*args)
    topo = tp.two_level_topology(n_intra, n_inter)
    t = cm.plan_times(p, topo)
    t.pop("lower_bound")
    best_tag = min(t, key=t.get)
    picked = cm.choose_plan(p, topo)
    assert t[picked.tag()] <= t[best_tag] * (1 + 1e-12)
    assert cm.choose_scheme(p, topo) == picked.tag()


# ---------------------------------------------------------------------------
# degenerate-topology exactness + the int overloads
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=20)
@given(PROFILE_ST, st.sampled_from([2, 4, 8, 16]))
def test_degenerate_topology_is_bit_identical(args, n):
    """flat_topology(n) with α=0, β=1 must reproduce the int-n cost model
    EXACTLY — same float values, same picks, same floor."""
    p = _profile(*args)
    topo = tp.flat_topology(n)
    assert cm.normalized_times(p, topo) == cm.normalized_times(p, n)
    assert cm.choose_scheme(p, topo) == cm.choose_scheme(p, n)
    lb_topo = cm.lower_bound(p, topo)
    assert lb_topo == cm.lower_bound(p, n)


def test_merged_profile_boundary_semantics():
    """The inter stage sees per-node density d(n_intra) as its d(1) —
    the capacity-growth boundary of the intra merge."""
    p = _profile(14, 0.01, 0.8, 0.5)
    m = cm.merged_profile(p, 4)
    assert m.d(1) == p.d(4)
    assert m.d(2) == p.d(8)
    assert m.M == p.M and m.vw == p.vw
    assert cm.merged_profile(p, 1) is p


def test_densify_after_intra_when_merged_density_crosses_break_even():
    """High enough d(1): the merged density saturates after the intra
    merge and the planner must densify the inter stage (pick a dense
    inter scheme) — while a genuinely sparse profile keeps a sparse
    inter stage."""
    topo = tp.two_level_topology(4, 8)
    dense_ish = _profile(20, 0.4, 1.0, 0.0)    # d(4) == 1.0: saturated
    plan = cm.choose_plan(dense_ish, topo)
    assert plan.scheme_at(1) == "dense", plan.tag()
    sparse = _profile(20, 0.001, 0.3, 0.0)     # d stays ~0.1% merged
    plan_s = cm.choose_plan(sparse, topo)
    assert plan_s.scheme_at(1) != "dense", plan_s.tag()


def test_stage_time_alpha_beta_terms():
    """time = α·rounds + β·words, size-1 levels are free."""
    p = _profile(14, 0.05, 0.8, 0.0)
    lvl = tp.Level(axis="x", size=8, alpha=7.0, beta=3.0)
    t = cm.stage_time("dense", p, lvl)
    want = 7.0 * 2 * (8 - 1) + 3.0 * cm.dense_allreduce(p, 8)
    assert t == pytest.approx(want, rel=1e-12)
    free = tp.Level(axis="x", size=1, alpha=7.0, beta=3.0)
    assert cm.stage_time("dense", p, free) == 0.0


def test_split_node_axes():
    """launch/mesh.py splits the data dim into (dp_inter, dp_intra) with
    intra-node ranks consecutive; node_size=1 is the identity."""
    from repro.launch.mesh import split_node_axes

    shape, axes = split_node_axes((8, 2), ("data", "model"), 4)
    assert shape == (2, 4, 2)
    assert axes == (tp.DP_INTER, tp.DP_INTRA, "model")
    assert split_node_axes((8, 2), ("data", "model"), 1) == \
        ((8, 2), ("data", "model"))
    shape_p, axes_p = split_node_axes((2, 8, 2), ("pod", "data", "model"), 2)
    assert shape_p == (2, 4, 2, 2)
    assert axes_p == ("pod", tp.DP_INTER, tp.DP_INTRA, "model")
    with pytest.raises(ValueError, match="node_size"):
        split_node_axes((8, 2), ("data", "model"), 3)
    with pytest.raises(ValueError, match="data"):
        split_node_axes((8,), ("model",), 2)


def test_sparcml_only_offered_at_pow2_levels():
    p = _profile(14, 0.01, 0.5, 0.0)
    topo = tp.two_level_topology(3, 8)   # non-pow2 intra
    tags = set(cm.plan_times(p, topo))
    assert not any(t.startswith("hier(sparcml@intra") for t in tags)
    assert any("sparcml@inter" in t for t in tags)

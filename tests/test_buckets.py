"""Bucketed sync scheduler (DESIGN.md §7): plan structure, SyncStats
reduction across buckets, and the invariance contract — synced values,
overflow counters, and byte accounting must not depend on ``bucket_bytes``
(including the ``None`` monolithic fallback, which must be bit-exact)."""
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.hypothesis_compat import given, settings, st

from repro.core import buckets as bk
from repro.core import costmodel, metrics
from repro.core.zen import GradSync, SyncConfig

N = 4
TABLE_ROWS, TABLE_D = 256, 8


def _shapes(extra_table=False):
    shapes = {
        "embed": {"table":
                  jax.ShapeDtypeStruct((TABLE_ROWS, TABLE_D), jnp.float32)},
        "mlp": {"w1": jax.ShapeDtypeStruct((32, 16), jnp.float32),
                "w2": jax.ShapeDtypeStruct((16, 32), jnp.float32),
                "b": jax.ShapeDtypeStruct((7,), jnp.float32)},
        "norm": {"g": jax.ShapeDtypeStruct((16,), jnp.float32),
                 "b16": jax.ShapeDtypeStruct((16,), jnp.bfloat16)},
    }
    if extra_table:
        shapes["out_embed"] = {
            "table": jax.ShapeDtypeStruct((64, 4), jnp.float32)}
    return shapes


def _grads(shapes, density=0.1, seed=0):
    """Per-worker gradients matching ``shapes``; tables row-sparse, values
    dyadic so accumulation order cannot perturb bit-exact comparisons."""
    key = jax.random.PRNGKey(seed)

    def leaf(path, s):
        # crc32, not hash(): PYTHONHASHSEED must not change the test data
        name_seed = zlib.crc32(bk.leaf_path_str(path).encode()) % (1 << 30)
        k = jax.random.fold_in(key, name_seed)
        g = jnp.round(jax.random.normal(k, (N, *s.shape)) * 256) / 256
        if "table" in bk.leaf_path_str(path):
            m = metrics.synth_sparse_masks(k, N, s.shape[0], density)
            g = g * m[..., None]
        return g.astype(s.dtype)

    return jax.tree_util.tree_map_with_path(leaf, shapes)


def _run(shapes, grads, bucket_bytes, scheme="zen", **kw):
    gs = GradSync(
        SyncConfig(scheme=scheme, density_budget=0.5,
                   bucket_bytes=bucket_bytes),
        ["embed/table", "out_embed/table"], shapes, N,
        data_axis="data", **kw)
    out, stats = jax.vmap(gs, axis_name="data")(grads)
    return gs, out, stats


# ---------------------------------------------------------------------------
# plan structure
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bucket_bytes", [None, 1, 100, 1024, 1 << 20])
def test_plan_covers_all_leaves_once(bucket_bytes):
    gs, _, _ = _run(_shapes(True), _grads(_shapes(True)), bucket_bytes)
    plan = gs.plan
    plan.validate()
    assert plan.n_leaves == len(jax.tree.leaves(_shapes(True)))
    for b in plan.buckets:
        if b.kind == bk.SPARSE:
            # row-sparse leaves are never fused or split
            assert len(b.slots) == 1
            assert "table" in b.slots[0].name
        else:
            # fused dense buckets respect the byte budget...
            if bucket_bytes is not None and len(b.slots) > 1:
                assert b.nbytes <= bucket_bytes
            # ...and never mix dtypes
            assert len({jnp.dtype(s.dtype) for s in b.slots}) == 1


def test_fallback_is_one_bucket_per_leaf():
    gs, _, _ = _run(_shapes(), _grads(_shapes()), None)
    assert len(gs.plan.buckets) == gs.plan.n_leaves
    assert all(len(b.slots) == 1 for b in gs.plan.buckets)


def test_bad_bucket_bytes_rejected():
    with pytest.raises(ValueError, match="bucket_bytes"):
        _run(_shapes(), _grads(_shapes()), 0)


# ---------------------------------------------------------------------------
# invariance to bucket size (the multi-bucket SyncStats reduction contract)
# ---------------------------------------------------------------------------

STAT_KEYS = ("sync/sparse_sent_words", "sync/dense_words", "sync/overflow")


def _assert_invariant(bucket_bytes, scheme="zen", density=0.1):
    shapes = _shapes(True)
    grads = _grads(shapes, density=density)
    _, out0, st0 = _run(shapes, grads, None, scheme)
    _, out1, st1 = _run(shapes, grads, bucket_bytes, scheme)
    for a, b in zip(jax.tree.leaves(out0), jax.tree.leaves(out1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for k in STAT_KEYS:
        np.testing.assert_array_equal(np.asarray(st0[k]), np.asarray(st1[k]))


@pytest.mark.parametrize("bucket_bytes", [1, 64, 257, 1024, 8192, 1 << 22])
@pytest.mark.parametrize("scheme", ["zen", "dense", "auto"])
def test_bucket_bytes_invariance(bucket_bytes, scheme):
    """Synced values bit-exact and overflow/byte accounting identical for
    every bucket size, including the None fallback as the reference."""
    _assert_invariant(bucket_bytes, scheme)


@given(st.integers(min_value=1, max_value=1 << 22))
@settings(max_examples=12, deadline=None)
def test_bucket_bytes_invariance_property(bucket_bytes):
    _assert_invariant(bucket_bytes)


@pytest.mark.parametrize("bucket_bytes", [None, 512, 1 << 20])
def test_zen_dense_parity_per_bucket_size(bucket_bytes):
    """zen == dense trainer-level (no-information-loss) at every bucket
    size: the schedule must not change what is synchronized."""
    shapes = _shapes()
    grads = _grads(shapes)
    _, out_z, _ = _run(shapes, grads, bucket_bytes, "zen")
    _, out_d, _ = _run(shapes, grads, bucket_bytes, "dense")
    for a, b in zip(jax.tree.leaves(out_z), jax.tree.leaves(out_d)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_overflow_surfaces_identically_across_bucket_sizes():
    """Undersized capacity must report the same overflow for every plan."""
    shapes = {"embed": {"table":
                        jax.ShapeDtypeStruct((256, 4), jnp.float32)},
              "w": jax.ShapeDtypeStruct((64,), jnp.float32)}
    grads = _grads(shapes, density=0.9)
    counts = []
    for bb in (None, 128, 1 << 20):
        gs = GradSync(SyncConfig(scheme="zen", density_budget=0.05,
                                 bucket_bytes=bb),
                      ["embed/table"], shapes, N, data_axis="data")
        _, stats = jax.vmap(gs, axis_name="data")(grads)
        counts.append(np.asarray(stats["sync/overflow"]))
    assert int(counts[0].sum()) > 0  # the capacity claim was violated...
    for c in counts[1:]:             # ...and every plan reports it alike
        np.testing.assert_array_equal(counts[0], c)


# ---------------------------------------------------------------------------
# stats reduction + per-tensor scheme selection
# ---------------------------------------------------------------------------

def test_reduce_stats_tags_and_totals():
    shapes = _shapes(True)
    gs, _, stats = _run(shapes, _grads(shapes), 1024, "zen")
    n_sparse = sum(b.kind == bk.SPARSE for b in gs.plan.buckets)
    n_dense = sum(b.kind == bk.DENSE for b in gs.plan.buckets)
    assert float(stats["sync/n_buckets"][0]) == len(gs.plan.buckets)
    assert float(stats["sync/buckets[zen]"][0]) == n_sparse
    assert float(stats["sync/buckets[dense]"][0]) == n_dense
    # dense byte accounting: ring allreduce words over all dense elements
    dense_elems = sum(b.size for b in gs.plan.buckets if b.kind == bk.DENSE)
    want = 2 * (N - 1) / N * dense_elems
    np.testing.assert_allclose(np.asarray(stats["sync/dense_words"])[0],
                               want, rtol=1e-6)


def test_auto_is_per_tensor_not_global():
    """With a measured profile only for one table, 'auto' must pick dense
    for the dense-ish profiled table and zen for the other — per tensor."""
    shapes = _shapes(True)
    dense_profile = costmodel.SparsityProfile(
        M=TABLE_ROWS, d=lambda i: 1.0, s=lambda n: 1.0, vw=TABLE_D)
    gs = GradSync(SyncConfig(scheme="auto", density_budget=0.01),
                  ["embed/table", "out_embed/table"], shapes, N,
                  data_axis="data",
                  profiles={"embed/table": dense_profile})
    schemes_by_name = {b.slots[0].name: b.scheme
                       for b in gs.plan.buckets if b.kind == bk.SPARSE}
    assert schemes_by_name["embed/table"] == "dense"
    assert schemes_by_name["out_embed/table"] == "zen"

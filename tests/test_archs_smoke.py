"""Per-architecture smoke tests (assignment requirement): reduced variant of
each family, one train step + one decode step on CPU, asserting output
shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.core.zen import SyncConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_mesh
from repro.optim.optimizers import OptConfig
from repro.train.build import attach_serve, attach_train, build_program
from repro.train.steps import TrainerConfig


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1, 1), ("data", "model"))


def _batch(cfg, seq, batch):
    b = next(iter(SyntheticLM(cfg, DataConfig(seq_len=seq, batch=batch))))
    return {k: jnp.asarray(v) for k, v in b.items()}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch, mesh):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    assert cfg.n_experts <= 4
    prog = build_program(cfg, mesh, TrainerConfig(
        opt=OptConfig(lr=1e-3), sync=SyncConfig(scheme="zen"), zero1=True))
    attach_train(prog, seq_len=32, global_batch=2)
    params = prog.init_params(0)
    opt = prog.init_opt(params)
    batch = _batch(cfg, 32, 2)
    shapes_before = jax.tree.map(lambda a: a.shape, params)
    # snapshot (params are donated into the step)
    leaf0_before = np.asarray(jax.tree.leaves(params)[0], np.float32).copy()
    p2, o2, m = prog.train_step(params, opt, batch)
    assert np.isfinite(float(m["loss"])), arch
    assert float(m["loss"]) > 0
    assert int(float(m["sync/overflow"])) == 0
    shapes_after = jax.tree.map(lambda a: a.shape, p2)
    assert shapes_before == shapes_after
    # params actually changed
    leaf0_after = np.asarray(jax.tree.leaves(p2)[0], np.float32)
    assert np.abs(leaf0_after - leaf0_before).max() > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_step_smoke(arch, mesh):
    cfg = get_config(arch).reduced()
    prog = build_program(cfg, mesh)
    attach_serve(prog, seq_len=64, global_batch=2, mode="decode")
    params = prog.init_params(0)
    cache = prog.fresh_cache()
    tok = jnp.zeros((2, 1), jnp.int32)
    for _ in range(2):
        tok, lmax, cache = prog.decode_step(params, cache, tok)
    assert tok.shape == (2, 1)
    assert (np.asarray(tok) >= 0).all()
    # padded vocab columns are masked out of the greedy argmax — the
    # sampled id must be a REAL token, not just < vocab_padded
    assert (np.asarray(tok) < cfg.vocab).all()
    assert np.isfinite(np.asarray(lmax, np.float32)).all(), arch
    assert int(cache["t"]) == 2


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-370m",
                                  "whisper-medium", "zamba2-1.2b",
                                  "minicpm3-4b"])
def test_prefill_matches_decode(arch, mesh):
    """Prefill then one decode must equal decoding the whole prompt
    step-by-step (cache-layout correctness)."""
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    prog = build_program(cfg, mesh)
    attach_serve(prog, seq_len=8, global_batch=2, mode="prefill")
    params = prog.init_params(0)
    batch = _batch(cfg, 8, 2)
    pf_batch = {k: v for k, v in batch.items() if k != "labels"}
    logits_pf, cache_pf = prog.prefill_step(params, pf_batch)

    attach_serve(prog, seq_len=8, global_batch=2, mode="decode")
    cache = prog.fresh_cache()
    if "cross" in cache and "cross" in cache_pf:
        cache = dict(cache, cross=cache_pf["cross"])
    lmax = None
    for i in range(8):
        tok = batch["tokens"][:, i: i + 1]
        _, lmax, cache = prog.decode_step(params, cache, tok)
    # compare greedy argmax of prefill's last-position logits vs decode's
    m_pf = np.asarray(jnp.max(logits_pf.astype(jnp.float32), axis=-1))
    np.testing.assert_allclose(m_pf.ravel(), np.asarray(lmax).ravel(),
                               rtol=2e-2, atol=2e-2)

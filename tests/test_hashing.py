"""Algorithm 1 (hierarchical hashing): correctness + Thm. 2 properties."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import hashing as H


def _random_indices(rng, universe, nnz, cap):
    pick = rng.choice(universe, size=min(nnz, universe), replace=False)
    idx = np.full(cap, H.EMPTY, np.int32)
    idx[: len(pick)] = np.sort(pick)
    return jnp.asarray(idx)


@pytest.mark.parametrize("n,k", [(4, 3), (16, 3), (8, 1), (32, 4)])
def test_no_information_loss(n, k):
    """Every input index appears exactly once in the output memory."""
    rng = np.random.default_rng(0)
    cap = 1024
    idx = _random_indices(rng, 100_000, 700, cap)
    seeds = H.make_seeds(0, k + 1)
    # The paper's serial-memory recipe r2 = r1/10 assumes k = 3 rehash
    # rounds; with a single round the surviving tail is ~4x larger (Fig.
    # 16b), so scale r2 accordingly to keep the no-overflow property.
    r2 = max(4, cap // (5 * n)) * (4 if k < 2 else 1)
    part = H.hierarchical_hash(idx, n=n, r1=2 * cap // n,
                               r2=r2, k=k, seeds=seeds)
    assert int(part.overflow) == 0
    got = np.asarray(part.memory)
    got = np.sort(got[got != H.EMPTY])
    want = np.asarray(idx)
    want = np.sort(want[want != H.EMPTY])
    np.testing.assert_array_equal(got, want)


def test_partition_consistency_across_workers():
    """h0 fixes the partition: the same index lands in the same partition on
    every worker regardless of what other indices that worker holds."""
    rng = np.random.default_rng(1)
    n, cap = 8, 512
    seeds = H.make_seeds(7, 4)
    shared = rng.choice(50_000, size=100, replace=False)
    placements = {}
    for w in range(4):
        own = rng.choice(50_000, size=200, replace=False)
        ids = np.unique(np.concatenate([shared, own]))
        idx = np.full(cap, H.EMPTY, np.int32)
        idx[: len(ids)] = ids
        part = H.hierarchical_hash(jnp.asarray(idx), n=n, r1=256, r2=32,
                                   k=3, seeds=seeds)
        mem = np.asarray(part.memory)
        for p in range(n):
            for v in mem[p][mem[p] != H.EMPTY]:
                assert placements.setdefault(int(v), p) == p


@settings(deadline=None, max_examples=15)
@given(st.integers(2, 32), st.integers(0, 2**31 - 2))
def test_h0_in_range(n, seed):
    idx = jnp.arange(1000, dtype=jnp.int32)
    p = H.partition_of(idx, n, H.make_seeds(seed, 1))
    assert (np.asarray(p) >= 0).all() and (np.asarray(p) < n).all()


@settings(deadline=None, max_examples=10)
@given(st.integers(0, 1000))
def test_imbalance_bound_thm2(seed):
    """Thm. 2: push imbalance <= 1 + O(sqrt(n log n / nnz)); we check the
    practical bound the paper reports (< 1.1 for real workloads) with a
    modest constant-factor cushion."""
    rng = np.random.default_rng(seed)
    n, cap = 16, 4096
    nnz = 3500
    idx = _random_indices(rng, 10_000_000, nnz, cap)
    seeds = H.make_seeds(seed, 4)
    p = H.partition_of(idx, n, seeds)
    counts = np.bincount(np.asarray(p)[np.asarray(idx) != H.EMPTY],
                         minlength=n + 1)[:n]
    imb = counts.max() * n / counts.sum()
    bound = 1 + 4 * np.sqrt(n * np.log(n) / nnz)
    assert imb <= bound, (imb, bound)


def test_skewed_input_still_balanced():
    """The paper's key claim: Zen balances even maximally skewed inputs
    (all non-zeros in one contiguous range — skewness ratio ~n)."""
    n, cap = 16, 2048
    idx = jnp.asarray(np.arange(1500, dtype=np.int32))  # one hot block
    idx = jnp.pad(idx, (0, cap - 1500), constant_values=H.EMPTY)
    seeds = H.make_seeds(3, 4)
    p = H.partition_of(idx, n, seeds)
    counts = np.bincount(np.asarray(p)[: 1500], minlength=n)
    imb = counts.max() * n / counts.sum()
    # positional split would give imbalance ~ n (= 16); hashing gives ~1
    assert imb < 1.35, imb


def test_strawman_loses_information():
    """Alg. 3 (single hash) collides and loses gradients; Alg. 1 does not —
    reproduces the Fig. 14 premise."""
    rng = np.random.default_rng(2)
    cap = 2048
    idx = _random_indices(rng, 1_000_000, 1800, cap)
    seeds = H.make_seeds(11, 4)
    mem, lost = H.strawman_hash(idx, n=8, r=1800 // 8, seed=int(seeds[0]))
    assert int(lost) > 0
    part = H.hierarchical_hash(idx, n=8, r1=2 * 1800 // 8, r2=60, k=3,
                               seeds=seeds)
    assert int(part.overflow) == 0


def test_rounds_histogram_k_study():
    """Fig. 16b: most writes succeed in round 1; later rounds and serial
    memory handle a shrinking tail."""
    rng = np.random.default_rng(4)
    cap = 4096
    idx = _random_indices(rng, 10_000_000, 4000, cap)
    seeds = H.make_seeds(5, 5)
    part = H.hierarchical_hash(idx, n=8, r1=1000, r2=120, k=4, seeds=seeds)
    hist = np.asarray(part.rounds_used, np.float64)
    assert hist[0] > 0.6 * hist.sum()
    assert (hist[:-1][1:] <= hist[:-1][:-1] + 1e-9).all()  # decreasing rounds


def test_compact_indices_roundtrip():
    mask = jnp.asarray(np.random.default_rng(0).uniform(size=777) < 0.2)
    idx, ov = H.compact_indices(mask, 256)
    assert int(ov) == 0
    got = np.asarray(idx)
    got = got[got != H.EMPTY]
    np.testing.assert_array_equal(got, np.nonzero(np.asarray(mask))[0])

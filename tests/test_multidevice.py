"""Multi-device integration (subprocess: 8 host devices).

Checks the claims that need a real multi-worker mesh:
  * TP/DP consistency: loss identical across mesh shapes (f32);
  * Zen sync == dense psum sync end-to-end at dp > 1 (the paper's
    no-information-loss claim at trainer level);
  * shard_map schemes == vmap simulation.

Split into two subprocesses so the known-broken cross-mesh comparison
(xfail) cannot mask the sync-level claims, which must stay hard failures.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PRELUDE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_mesh
    from repro.train.build import build_program, attach_train
    from repro.train.steps import TrainerConfig
    from repro.core.zen import SyncConfig
    from repro.data.pipeline import SyntheticLM, DataConfig

    def run(arch, mesh_shape, scheme, steps=2, compress="none"):
        # capacity_factor high enough that no tokens drop: MoE drop
        # boundaries legitimately depend on per-shard capacity, which
        # would otherwise differ across mesh shapes
        cfg = dataclasses.replace(get_config(arch).reduced(),
                                  dtype=jnp.float32, capacity_factor=4.0)
        mesh = make_mesh(mesh_shape, ("data", "model"))
        prog = build_program(cfg, mesh,
                             TrainerConfig(sync=SyncConfig(
                                 scheme=scheme, compress=compress,
                                 bucket_bytes=(1 << 15)
                                 if compress != "none" else None)))
        attach_train(prog, seq_len=32, global_batch=4)
        params = prog.init_params(0)
        opt = prog.init_opt(params)
        b = next(iter(SyntheticLM(cfg, DataConfig(seq_len=32, batch=4))))
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        losses = []
        for _ in range(steps):
            params, opt, m = prog.train_step(params, opt, batch)
            losses.append(float(m["loss"]))
        return losses, {k: float(v) for k, v in m.items()
                        if k.startswith("sync/")}
""")

WORKER_CROSS_MESH = PRELUDE + textwrap.dedent("""
    for arch in ["qwen2-0.5b", "mamba2-370m", "olmoe-1b-7b"]:
        base, _ = run(arch, (1, 1), "zen")
        tp, _ = run(arch, (2, 4), "zen")
        for a, b_ in zip(base, tp):
            assert abs(a - b_) < 1e-3, (arch, base, tp)
        print("CONSISTENT", arch, base, tp)
    print("ALL_OK")
""")

WORKER_SYNC = PRELUDE + textwrap.dedent("""
    # Zen == dense end-to-end at dp=4 (f32 exact-ish)
    for arch in ["qwen2-0.5b"]:
        zen, zen_m = run(arch, (4, 2), "zen", steps=3)
        dense, dense_m = run(arch, (4, 2), "dense", steps=3)
        for a, b_ in zip(zen, dense):
            assert abs(a - b_) < 1e-3, (zen, dense)
        zen_words = zen_m["sync/sparse_sent_words"]
        assert zen_words > 0, "zen reported no sparse traffic at dp=4"
        print("ZEN==DENSE", arch, zen, dense, zen_words)

    # EF top-k compression end-to-end on the mesh (DESIGN.md §8): the
    # sparsified run must train (finite, broadly tracking dense over a
    # few steps), sync its compressed buckets with a sparse scheme
    # chosen by 'auto', and cut the dense-bucket wire volume hard
    comp, comp_m = run("qwen2-0.5b", (4, 2), "auto", steps=3,
                       compress="topk:0.02")
    assert all(np.isfinite(x) for x in comp), comp
    # step-0 loss is pre-update (same seed, same params): must match dense
    assert abs(comp[0] - dense[0]) < 1e-3, (comp[0], dense[0])
    assert comp_m.get("sync/compressed_buckets", 0) > 0, comp_m
    comp_wire = comp_m["sync/sparse_sent_words"] + comp_m["sync/dense_words"]
    dense_wire = dense_m["sync/sparse_sent_words"] + dense_m["sync/dense_words"]
    assert comp_wire < 0.25 * dense_wire, (comp_wire, dense_wire)
    assert comp_m["sync/overflow"] == 0, comp_m
    print("EF_COMPRESS_ON_MESH", comp, comp_wire, dense_wire)

    # MoE token-sharded a2a dispatch == replicated dispatch (§Perf B1)
    def run_moe(a2a):
        cfg = dataclasses.replace(get_config("olmoe-1b-7b").reduced(),
                                  dtype=jnp.float32, capacity_factor=4.0)
        mesh = make_mesh((2, 4), ("data", "model"))
        prog = build_program(cfg, mesh,
                             TrainerConfig(sync=SyncConfig(scheme="dense")),
                             moe_a2a=a2a)
        attach_train(prog, seq_len=32, global_batch=4)
        params = prog.init_params(0)
        opt = prog.init_opt(params)
        b = next(iter(SyntheticLM(cfg, DataConfig(seq_len=32, batch=4))))
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, m = prog.train_step(params, opt, batch)
        _, _, m2 = prog.train_step(params, opt, batch)
        return float(m["loss"]), float(m2["loss"])

    base_moe = run_moe(False)
    a2a_moe = run_moe(True)
    assert abs(base_moe[0] - a2a_moe[0]) < 1e-4, (base_moe, a2a_moe)
    assert abs(base_moe[1] - a2a_moe[1]) < 1e-3, (base_moe, a2a_moe)
    print("MOE_A2A==REPLICATED", base_moe, a2a_moe)
    print("ALL_OK")
""")


def _run_worker(script: str) -> None:
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=3000)
    assert "ALL_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-4000:]


@pytest.mark.slow
@pytest.mark.xfail(
    reason="pre-existing model-layer TP inconsistency: first-step loss "
           "differs between (1,1) and (2,4) meshes for EVERY sync scheme "
           "(dense included), so the mismatch is in the TP forward/init "
           "path, not gradient synchronization. Tracked in ROADMAP.md "
           "'Open items' for a model-zoo PR.  strict=True: if a refactor "
           "fixes the forward path, this must FAIL so the xfail (and the "
           "ROADMAP entry) get removed instead of rotting.",
    strict=True)
def test_cross_mesh_consistency():
    _run_worker(WORKER_CROSS_MESH)


@pytest.mark.slow
def test_sync_schemes_on_mesh():
    """zen == dense at dp=4 and MoE a2a == replicated — hard assertions;
    a zen fast-path regression on a real mesh must fail, not xfail."""
    _run_worker(WORKER_SYNC)

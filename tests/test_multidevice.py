"""Multi-device integration (subprocess: 8 host devices).

Checks the claims that need a real multi-worker mesh:
  * TP/DP consistency: loss identical across mesh shapes (f32);
  * Zen sync == dense psum sync end-to-end at dp > 1 (the paper's
    no-information-loss claim at trainer level);
  * shard_map schemes == vmap simulation.

Split into two subprocesses so the known-broken cross-mesh comparison
(xfail) cannot mask the sync-level claims, which must stay hard failures.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PRELUDE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_mesh
    from repro.train.build import build_program, attach_train
    from repro.train.steps import TrainerConfig
    from repro.core.zen import SyncConfig
    from repro.data.pipeline import SyntheticLM, DataConfig

    def run(arch, mesh_shape, scheme, steps=2):
        # capacity_factor high enough that no tokens drop: MoE drop
        # boundaries legitimately depend on per-shard capacity, which
        # would otherwise differ across mesh shapes
        cfg = dataclasses.replace(get_config(arch).reduced(),
                                  dtype=jnp.float32, capacity_factor=4.0)
        mesh = make_mesh(mesh_shape, ("data", "model"))
        prog = build_program(cfg, mesh,
                             TrainerConfig(sync=SyncConfig(scheme=scheme)))
        attach_train(prog, seq_len=32, global_batch=4)
        params = prog.init_params(0)
        opt = prog.init_opt(params)
        b = next(iter(SyntheticLM(cfg, DataConfig(seq_len=32, batch=4))))
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        losses = []
        for _ in range(steps):
            params, opt, m = prog.train_step(params, opt, batch)
            losses.append(float(m["loss"]))
        return losses, float(m.get("sync/sparse_sent_words", 0.0))
""")

WORKER_CROSS_MESH = PRELUDE + textwrap.dedent("""
    for arch in ["qwen2-0.5b", "mamba2-370m", "olmoe-1b-7b"]:
        base, _ = run(arch, (1, 1), "zen")
        tp, _ = run(arch, (2, 4), "zen")
        for a, b_ in zip(base, tp):
            assert abs(a - b_) < 1e-3, (arch, base, tp)
        print("CONSISTENT", arch, base, tp)
    print("ALL_OK")
""")

WORKER_SYNC = PRELUDE + textwrap.dedent("""
    # Zen == dense end-to-end at dp=4 (f32 exact-ish)
    for arch in ["qwen2-0.5b"]:
        zen, zen_words = run(arch, (4, 2), "zen", steps=3)
        dense, _ = run(arch, (4, 2), "dense", steps=3)
        for a, b_ in zip(zen, dense):
            assert abs(a - b_) < 1e-3, (zen, dense)
        assert zen_words > 0, "zen reported no sparse traffic at dp=4"
        print("ZEN==DENSE", arch, zen, dense, zen_words)

    # MoE token-sharded a2a dispatch == replicated dispatch (§Perf B1)
    def run_moe(a2a):
        cfg = dataclasses.replace(get_config("olmoe-1b-7b").reduced(),
                                  dtype=jnp.float32, capacity_factor=4.0)
        mesh = make_mesh((2, 4), ("data", "model"))
        prog = build_program(cfg, mesh,
                             TrainerConfig(sync=SyncConfig(scheme="dense")),
                             moe_a2a=a2a)
        attach_train(prog, seq_len=32, global_batch=4)
        params = prog.init_params(0)
        opt = prog.init_opt(params)
        b = next(iter(SyntheticLM(cfg, DataConfig(seq_len=32, batch=4))))
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, m = prog.train_step(params, opt, batch)
        _, _, m2 = prog.train_step(params, opt, batch)
        return float(m["loss"]), float(m2["loss"])

    base_moe = run_moe(False)
    a2a_moe = run_moe(True)
    assert abs(base_moe[0] - a2a_moe[0]) < 1e-4, (base_moe, a2a_moe)
    assert abs(base_moe[1] - a2a_moe[1]) < 1e-3, (base_moe, a2a_moe)
    print("MOE_A2A==REPLICATED", base_moe, a2a_moe)
    print("ALL_OK")
""")


def _run_worker(script: str) -> None:
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=3000)
    assert "ALL_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-4000:]


@pytest.mark.slow
@pytest.mark.xfail(
    reason="pre-existing model-layer TP inconsistency: first-step loss "
           "differs between (1,1) and (2,4) meshes for EVERY sync scheme "
           "(dense included), so the mismatch is in the TP forward/init "
           "path, not gradient synchronization. Tracked for a model-zoo PR.",
    strict=False)
def test_cross_mesh_consistency():
    _run_worker(WORKER_CROSS_MESH)


@pytest.mark.slow
def test_sync_schemes_on_mesh():
    """zen == dense at dp=4 and MoE a2a == replicated — hard assertions;
    a zen fast-path regression on a real mesh must fail, not xfail."""
    _run_worker(WORKER_SYNC)

"""Multi-device integration (subprocess: 8 host devices).

Checks the claims that need a real multi-worker mesh:
  * cross-mesh parity (DESIGN.md §9): loss identical across mesh shapes
    (f32) for EVERY sync scheme — a fast 2-config subset runs in tier-1
    on every CI run, the full {arch} x {mesh} x {scheme} matrix runs in
    the CI multidevice job via ``make test-crossmesh``
    (``REPRO_CROSSMESH=full``);
  * Zen sync == dense psum sync end-to-end at dp > 1 (the paper's
    no-information-loss claim at trainer level);
  * shard_map schemes == vmap simulation.

Split into separate subprocesses so a cross-mesh model-layer regression
cannot mask the sync-level claims (and vice versa).
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PRELUDE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_mesh
    from repro.train.build import build_program, attach_train
    from repro.train.steps import TrainerConfig
    from repro.core.zen import SyncConfig
    from repro.data.pipeline import SyntheticLM, DataConfig

    def run(arch, mesh_shape, scheme, steps=2, compress="none",
            node_size=1):
        # capacity_factor high enough that no tokens drop: MoE drop
        # boundaries legitimately depend on per-shard capacity, which
        # would otherwise differ across mesh shapes
        cfg = dataclasses.replace(get_config(arch).reduced(),
                                  dtype=jnp.float32, capacity_factor=4.0)
        mesh = make_mesh(mesh_shape, ("data", "model"),
                         node_size=node_size)
        prog = build_program(cfg, mesh,
                             TrainerConfig(sync=SyncConfig(
                                 scheme=scheme, compress=compress,
                                 bucket_bytes=(1 << 15)
                                 if compress != "none" else None)))
        attach_train(prog, seq_len=32, global_batch=4)
        params = prog.init_params(0)
        opt = prog.init_opt(params)
        b = next(iter(SyntheticLM(cfg, DataConfig(seq_len=32, batch=4))))
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        losses = []
        for _ in range(steps):
            params, opt, m = prog.train_step(params, opt, batch)
            losses.append(float(m["loss"]))
        return losses, {k: float(v) for k, v in m.items()
                        if k.startswith("sync/")}
""")

# --- cross-mesh parity (DESIGN.md §9) --------------------------------------
# Scheme variants of the parity matrix: (sync scheme, compress spec).
CROSS_MESH_LIB = PRELUDE + textwrap.dedent("""
    SCHEMES = {
        "dense":   ("dense", "none"),
        "zen":     ("zen", "none"),
        "auto":    ("auto", "none"),
        "topk-ef": ("auto", "topk:0.02"),
    }

    def check_parity(arch, meshes, schemes, steps=4, tol=1e-3,
                     lossy_band=1.0):
        '''Hard loss-parity matrix: for each scheme, every mesh must match
        the (1,1) baseline at step 0 and step ``steps-1``.

        Lossless sync (dense/zen/auto) shares one (1,1) baseline — at
        dp=1 the data sync is the identity, so their trajectories are
        the same run — which makes the lossless legs simultaneously a
        zen==dense==auto parity check.  Lossy compression (topk EF) gets
        exact step-0 parity (the pre-update forward is mesh-invariant)
        but only a broad band + progress check afterwards: per-worker
        top-k picks are a function of the LOCAL gradient, so the update
        direction legitimately depends on the dp partition (DESIGN.md
        §9; observed cross-mesh step-3 drift up to 0.44 on a ~5 loss).
        '''
        base = {}
        for name in schemes:
            scheme, compress = SCHEMES[name]
            lossy = compress != "none"
            bkey = "lossy" if lossy else "lossless"
            bscheme, bcompress = ("auto", compress) if lossy \
                else ("dense", "none")
            if bkey not in base:
                base[bkey], _ = run(arch, (1, 1), bscheme, steps=steps,
                                    compress=bcompress)
            b = base[bkey]
            assert all(np.isfinite(x) for x in b), (arch, name, b)
            for ms in meshes:
                if ms == (1, 1) and (scheme, compress) == (bscheme,
                                                           bcompress):
                    continue    # that run IS the baseline
                ls, _ = run(arch, ms, scheme, steps=steps,
                            compress=compress)
                assert all(np.isfinite(x) for x in ls), (arch, name, ms, ls)
                d0, dN = abs(ls[0] - b[0]), abs(ls[-1] - b[-1])
                assert d0 < tol, ("step-0", arch, name, ms, ls, b)
                if lossy:
                    assert dN < lossy_band, \
                        ("step-%d" % (steps - 1), arch, name, ms, ls, b)
                    # EF must still train on every mesh, not stall
                    assert ls[-1] < ls[0] - 0.3, (arch, name, ms, ls)
                else:
                    assert dN < tol, \
                        ("step-%d" % (steps - 1), arch, name, ms, ls, b)
                print("PARITY", arch, name, ms, "d0=%.2e dN=%.2e" % (d0, dN))
""")

WORKER_CROSS_MESH_FAST = CROSS_MESH_LIB + textwrap.dedent("""
    check_parity("qwen2-0.5b", [(1, 1), (2, 4)], ["zen"])
    check_parity("mamba2-370m", [(1, 1), (4, 2)], ["dense"])
    print("ALL_OK")
""")

# full matrix: {attention, MoE, SSM} x 4 meshes x 4 schemes.  olmoe's
# reduced config has 4 experts (experts shard over model), so its pure-TP
# mesh is capped at tp=4 and the tp=8 slot becomes pure-DP (8,1) —
# make_ctx rejects (1,8) for it with a config-named ValueError, which
# tests/test_mesh_invariance.py asserts.
MATRIX_MESHES = {
    "qwen2-0.5b": [(1, 1), (1, 8), (2, 4), (4, 2)],
    "olmoe-1b-7b": [(1, 1), (8, 1), (2, 4), (4, 2)],
    "mamba2-370m": [(1, 1), (1, 8), (2, 4), (4, 2)],
}


# f32 lossless tolerance per arch: attention/SSM sit at reduction-order
# noise (observed <= 1e-6); MoE's renormalized top-k router amplifies it
# through discrete routing (observed step-3 drift up to 7.6e-4), so the
# MoE gate gets headroom over the observation instead of sitting on it.
MATRIX_TOL = {"qwen2-0.5b": 1e-3, "olmoe-1b-7b": 2.5e-3,
              "mamba2-370m": 1e-3}


def _matrix_worker(arch: str) -> str:
    return CROSS_MESH_LIB + textwrap.dedent(f"""
        check_parity({arch!r}, {MATRIX_MESHES[arch]!r}, list(SCHEMES),
                     tol={MATRIX_TOL[arch]!r})
        print("ALL_OK")
    """)

WORKER_SYNC = PRELUDE + textwrap.dedent("""
    # Zen == dense end-to-end at dp=4 (f32 exact-ish)
    for arch in ["qwen2-0.5b"]:
        zen, zen_m = run(arch, (4, 2), "zen", steps=3)
        dense, dense_m = run(arch, (4, 2), "dense", steps=3)
        for a, b_ in zip(zen, dense):
            assert abs(a - b_) < 1e-3, (zen, dense)
        zen_words = zen_m["sync/sparse_sent_words"]
        assert zen_words > 0, "zen reported no sparse traffic at dp=4"
        print("ZEN==DENSE", arch, zen, dense, zen_words)

    # EF top-k compression end-to-end on the mesh (DESIGN.md §8): the
    # sparsified run must train (finite, broadly tracking dense over a
    # few steps), sync its compressed buckets with a sparse scheme
    # chosen by 'auto', and cut the dense-bucket wire volume hard
    comp, comp_m = run("qwen2-0.5b", (4, 2), "auto", steps=3,
                       compress="topk:0.02")
    assert all(np.isfinite(x) for x in comp), comp
    # step-0 loss is pre-update (same seed, same params): must match dense
    assert abs(comp[0] - dense[0]) < 1e-3, (comp[0], dense[0])
    assert comp_m.get("sync/compressed_buckets", 0) > 0, comp_m
    comp_wire = comp_m["sync/sparse_sent_words"] + comp_m["sync/dense_words"]
    dense_wire = dense_m["sync/sparse_sent_words"] + dense_m["sync/dense_words"]
    assert comp_wire < 0.25 * dense_wire, (comp_wire, dense_wire)
    assert comp_m["sync/overflow"] == 0, comp_m
    print("EF_COMPRESS_ON_MESH", comp, comp_wire, dense_wire)

    # MoE token-sharded a2a dispatch == replicated dispatch (§Perf B1)
    def run_moe(a2a):
        cfg = dataclasses.replace(get_config("olmoe-1b-7b").reduced(),
                                  dtype=jnp.float32, capacity_factor=4.0)
        mesh = make_mesh((2, 4), ("data", "model"))
        prog = build_program(cfg, mesh,
                             TrainerConfig(sync=SyncConfig(scheme="dense")),
                             moe_a2a=a2a)
        attach_train(prog, seq_len=32, global_batch=4)
        params = prog.init_params(0)
        opt = prog.init_opt(params)
        b = next(iter(SyntheticLM(cfg, DataConfig(seq_len=32, batch=4))))
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, m = prog.train_step(params, opt, batch)
        _, _, m2 = prog.train_step(params, opt, batch)
        return float(m["loss"]), float(m2["loss"])

    base_moe = run_moe(False)
    a2a_moe = run_moe(True)
    assert abs(base_moe[0] - a2a_moe[0]) < 1e-4, (base_moe, a2a_moe)
    assert abs(base_moe[1] - a2a_moe[1]) < 1e-3, (base_moe, a2a_moe)
    print("MOE_A2A==REPLICATED", base_moe, a2a_moe)
    print("ALL_OK")
""")


# --- hierarchical topology (DESIGN.md §10) ----------------------------------
# node_size splits dp into (dp_inter, dp_intra); hierarchical runs must
# match the flat run's trajectory: the two-level plan changes WHERE bytes
# move, never what is aggregated.  Fast subset (tier-1): (8,1) at
# node_size=2, dense + zen.  Full matrix (CI hierarchical leg,
# REPRO_HIER=full): meshes {(1,1),(8,1),(2,4)} x node_size {1,2,4} with
# non-dividing combos asserted to fail fast in make_ctx.
HIER_LIB = PRELUDE + textwrap.dedent("""
    def check_hier(arch, mesh, schemes, node_sizes, steps=3, tol=1e-3):
        dp = mesh[0]
        for scheme in schemes:
            flat, flat_m = run(arch, mesh, scheme, steps=steps)
            assert all(np.isfinite(x) for x in flat), (arch, scheme, flat)
            for ns in node_sizes:
                if ns <= 1:
                    continue
                if dp % ns != 0:
                    # invalid grouping must fail fast with a config error
                    try:
                        run(arch, mesh, scheme, steps=1, node_size=ns)
                    except ValueError as e:
                        assert "node_size" in str(e), e
                        print("REJECTED", arch, mesh, ns)
                        continue
                    raise AssertionError(
                        f"node_size={ns} should not divide dp={dp}")
                ls, m = run(arch, mesh, scheme, steps=steps, node_size=ns)
                d0, dN = abs(ls[0] - flat[0]), abs(ls[-1] - flat[-1])
                assert d0 < tol, ("step-0", arch, scheme, ns, ls, flat)
                assert dN < tol, ("step-N", arch, scheme, ns, ls, flat)
                assert m["sync/overflow"] == 0, m
                if ns < dp:   # >1 node: the per-level split must surface
                    assert "sync/inter_words" in m, sorted(m)
                    assert m["sync/inter_words"] > 0, m
                print("HIER_PARITY", arch, mesh, scheme, "ns=%d" % ns,
                      "d0=%.2e dN=%.2e inter=%.0f" % (
                          d0, dN, m.get("sync/inter_words", -1)))
""")

WORKER_HIER_FAST = HIER_LIB + textwrap.dedent("""
    check_hier("qwen2-0.5b", (8, 1), ["dense", "zen"], [2])
    print("ALL_OK")
""")

HIER_MATRIX = [("qwen2-0.5b", (8, 1)), ("qwen2-0.5b", (2, 4)),
               ("qwen2-0.5b", (1, 1))]


def _hier_matrix_worker(arch: str, mesh) -> str:
    return HIER_LIB + textwrap.dedent(f"""
        check_hier({arch!r}, {mesh!r}, ["dense", "zen", "auto"], [1, 2, 4])
        print("ALL_OK")
    """)


def _run_worker(script: str) -> None:
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=3000)
    assert "ALL_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-4000:]


@pytest.mark.slow
def test_cross_mesh_consistency():
    """Cross-mesh loss parity, HARD assertion (fast 2-config subset).

    Replaces the PR-1..3 strict xfail: the model-layer TP inconsistency
    was mesh-dependent *init* — legacy non-partitionable threefry drew
    different bits for row-sharded leaves under a sharded out-sharding —
    fixed by jax_threefry_partitionable (repro/__init__.py) + the
    path-keyed ParamBuilder; any regression must fail tier-1 on every
    CI run, not just the multidevice job."""
    _run_worker(WORKER_CROSS_MESH_FAST)


@pytest.mark.slow
@pytest.mark.parametrize("arch", list(MATRIX_MESHES))
def test_cross_mesh_parity_matrix(arch):
    """Full §9 parity matrix for one architecture (4 meshes x 4 schemes).

    Runs when REPRO_CROSSMESH=full (``make test-crossmesh``, wired into
    the CI multidevice job); skipped in plain tier-1 where the fast
    subset above covers the invariant."""
    if os.environ.get("REPRO_CROSSMESH") != "full":
        pytest.skip("full parity matrix runs via `make test-crossmesh`")
    _run_worker(_matrix_worker(arch))


@pytest.mark.slow
def test_sync_schemes_on_mesh():
    """zen == dense at dp=4 and MoE a2a == replicated — hard assertions;
    a zen fast-path regression on a real mesh must fail, not xfail."""
    _run_worker(WORKER_SYNC)


@pytest.mark.slow
def test_hierarchical_sync_on_mesh():
    """Hierarchical (node-split) sync == flat sync on a real 8-device
    mesh, loss-parity hard assertion (fast subset; the full
    mesh x node_size matrix runs via ``make test-hier``)."""
    _run_worker(WORKER_HIER_FAST)


@pytest.mark.slow
@pytest.mark.parametrize("arch,mesh", HIER_MATRIX,
                         ids=lambda v: str(v).replace(" ", ""))
def test_hierarchical_parity_matrix(arch, mesh):
    """Full §10 invariance matrix: meshes {(1,1),(8,1),(2,4)} x
    node_size {1,2,4} x {dense, zen, auto}, non-dividing combos rejected
    with config-named errors.  Runs when REPRO_HIER=full
    (``make test-hier``, wired into the CI multidevice job)."""
    if os.environ.get("REPRO_HIER") != "full":
        pytest.skip("full hierarchical matrix runs via `make test-hier`")
    _run_worker(_hier_matrix_worker(arch, mesh))

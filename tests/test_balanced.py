"""Balanced (Ok-Topk-family) sparse allreduce + the scheme registry.

Three contracts under test:

1. ``balanced_sync`` correctness and the *balanced bound*: buffers sized
   at ``nnz_global/n + one-bin slack`` never overflow no matter how
   skewed the per-worker nonzeros are (the property agsparse/sparse_ps
   lack — their correct provisioning grows with ``n * nnz_max``).  The
   hypothesis sweep drives the skew fraction from uniform to one worker
   holding 100% of nonzeros.

2. The planner: ``choose_plan`` picks balanced over zen / agsparse /
   sparcml / dense on a profile whose aggregated density sits below
   zen's bitmap break-even (d(n) < 1/32 - 2*bins/M), flat and as a hier
   stage, and stays argmin-consistent with ``plan_times``.

3. The registry API: config-named StageArgs validation errors, unknown
   schemes listing the registered names, analytic-only schemes rejected
   in plan tags, CLI choices derived (not hardcoded), and registry
   coverage (every scheme has volume + rounds + a tier-1 parity test).
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.core import costmodel as cm
from repro.core import registry as rg
from repro.core import schemes
from repro.core import topology as tp
from repro.core.registry import StageArgs

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))

N, M = 4, 4096
T = 512                       # total nonzeros across all workers
BINW = M // rg.BALANCED_BINS  # bin width at the default resolution

# balanced bound: a destination's contiguous range holds at most
# total/n + (count of one boundary bin) multiset entries; one bin holds
# at most min(T, n * bin_width) entries (duplicates across workers)
CAP = T // N + min(T, N * BINW)


def _skewed_workers(frac: float, seed: int) -> np.ndarray:
    """[N, M] f32 with exactly T nonzeros total; ``frac`` of them on
    worker 0, the rest spread over the other workers."""
    rng = np.random.default_rng(seed)
    g = np.zeros((N, M), np.float32)
    hot = int(frac * T)
    counts = [hot] + [0] * (N - 1)
    for j, _ in enumerate(range(T - hot)):
        counts[1 + j % (N - 1)] += 1
    for i, c in enumerate(counts):
        pos = rng.choice(M, size=c, replace=False)
        g[i, pos] = rng.standard_normal(c).astype(np.float32)
    return g


def _run_balanced(g: np.ndarray):
    return schemes.simulate(schemes.balanced_sync, jnp.asarray(g),
                            n=N, cap_push=CAP, cap_pull=CAP)


# ---------------------------------------------------------------------------
# 1. correctness + the balanced bound
# ---------------------------------------------------------------------------

def test_balanced_matches_dense_oracle_uniform():
    g = _skewed_workers(0.25, seed=0)
    out, stats = _run_balanced(g)
    assert int(np.asarray(stats.overflow).sum()) == 0
    np.testing.assert_allclose(np.asarray(out),
                               g.sum(0)[None].repeat(N, 0), atol=1e-4)


def test_balanced_full_skew_zero_overflow_with_bound_sized_buffers():
    """One worker holds 100% of the nonzeros: T entries rebalance to
    ~T/N per destination, so buffers sized by the balanced bound (CAP,
    independent of nnz_max) do not overflow — the exact regime where
    agsparse needs capacity == nnz_max == T."""
    g = _skewed_workers(1.0, seed=1)
    out, stats = _run_balanced(g)
    assert int(np.asarray(stats.overflow).sum()) == 0
    np.testing.assert_allclose(np.asarray(out),
                               g.sum(0)[None].repeat(N, 0), atol=1e-4)


def test_balanced_beats_agsparse_wire_under_full_skew():
    """At 100% skew agsparse must provision capacity = nnz_max = T and
    its bottleneck worker ships (n-1) * T COO pairs; balanced ships the
    rebalanced ~T/n-per-destination volume and wins on the wire."""
    g = _skewed_workers(1.0, seed=2)
    _, st_b = _run_balanced(g)
    _, st_a = schemes.simulate(schemes.agsparse_sync, jnp.asarray(g),
                               capacity=T)
    bal = float(np.asarray(st_b.sent_words).max())
    ags = float(np.asarray(st_a.sent_words).max())
    assert bal < ags, (bal, ags)


@settings(max_examples=12, deadline=None)
@given(frac=st.floats(min_value=0.0, max_value=1.0), seed=st.integers(0, 63))
def test_balanced_bound_holds_across_skew_sweep(frac, seed):
    """Property: for ANY skew (uniform .. one-worker-holds-all), the
    bound-sized buffers (CAP = T/n + one-bin slack — no nnz_max term)
    absorb the exchange with zero overflow and exact aggregation."""
    g = _skewed_workers(frac, seed)
    out, stats = _run_balanced(g)
    assert int(np.asarray(stats.overflow).sum()) == 0
    np.testing.assert_allclose(np.asarray(out),
                               g.sum(0)[None].repeat(N, 0), atol=1e-4)


# ---------------------------------------------------------------------------
# 2. planner integration
# ---------------------------------------------------------------------------

def _skewed_profile(m: int = 1 << 16, d: float = 0.005) -> cm.SparsityProfile:
    """The MoE-router regime: all workers hit the SAME hot region
    (full overlap: d(i) = d for all i) with per-range skew 8.  Full
    overlap is where agsparse's (n-1)·d·M centralization and sparcml's
    per-stage re-exchange waste the most, and d(n)·M sits below zen's
    M/32 bitmap-pull break-even — balanced's rebalanced COO undercuts
    every incumbent candidate at n = 8."""
    return cm.SparsityProfile(M=m, d=lambda i: d, s=lambda n: 8.0)


def test_choose_plan_picks_balanced_flat():
    p = _skewed_profile()
    plan = cm.choose_plan(p, tp.flat_topology(8))
    assert plan.tag() == "balanced"


def test_choose_plan_picks_balanced_hier_stage():
    """On a two-level topology (beta-dominated links: the fat-gradient
    regime where word volume, not latency, decides), balanced must win
    the 8-wide inter level; argmin-consistency with the published
    per-plan times guards against candidate-set drift."""
    p = _skewed_profile()
    topo = tp.two_level_topology(2, 8, alpha_intra=0.0, beta_intra=1.0,
                                 alpha_inter=0.0, beta_inter=1.0)
    plan = cm.choose_plan(p, topo)
    assert "balanced" in [s.scheme for s in plan.stages], plan.tag()
    times = cm.plan_times(p, topo)
    times.pop("lower_bound")
    assert plan.tag() == min(times, key=times.get)


def test_balanced_volume_has_no_skew_penalty():
    """The point of the rebalance: sparse_ps pays s(n); balanced does
    not.  With a skew-10 profile the balanced volume is unchanged while
    sparse_ps scales by the skew factor."""
    base = cm.SparsityProfile(M=1 << 16, d=lambda i: min(1.0, i * 0.001),
                              s=lambda n: 1.0)
    skew = cm.SparsityProfile(M=1 << 16, d=lambda i: min(1.0, i * 0.001),
                              s=lambda n: 10.0)
    assert cm.balanced(skew, 8) == cm.balanced(base, 8)
    assert cm.sparse_ps(skew, 8) == pytest.approx(10 * cm.sparse_ps(base, 8))


def test_balanced_floored_by_optimal_curve():
    p = _skewed_profile()
    for n in (2, 4, 8, 16):
        assert cm.balanced(p, n) >= cm.balanced_parallelism(p, n)


# ---------------------------------------------------------------------------
# 3. registry API
# ---------------------------------------------------------------------------

def test_unknown_scheme_error_lists_registered_names():
    with pytest.raises(ValueError, match="registered schemes are"):
        schemes.stage_sync("bogus", jnp.zeros((8,)), axis="x", n=2)
    with pytest.raises(ValueError, match="balanced"):
        rg.get_scheme("not-a-scheme")


def test_stray_stage_arg_rejected_with_config_named_error():
    with pytest.raises(ValueError, match="does not consume stage arg"):
        schemes.stage_sync("agsparse", jnp.zeros((8,)), axis="x", n=2,
                           capacity=4, block=2)


def test_missing_required_stage_arg_rejected():
    with pytest.raises(ValueError, match="requires stage arg"):
        schemes.stage_sync("balanced", jnp.zeros((8,)), axis="x", n=2)
    with pytest.raises(ValueError, match="layout"):
        schemes.stage_sync("zen", jnp.zeros((8,)), axis="x", n=2)


def test_stage_args_and_loose_kwargs_are_exclusive():
    with pytest.raises(ValueError, match="not both"):
        schemes.stage_sync("agsparse", jnp.zeros((8,)), axis="x", n=2,
                           stage_args=StageArgs(capacity=4), capacity=4)


def test_plan_tags_reject_analytic_only_schemes():
    for tag in ("lower_bound", "balanced_parallelism",
                "hier(balanced_parallelism@intra,dense@inter)"):
        with pytest.raises(ValueError, match="analytic-only"):
            tp.parse_plan(tag)


def test_capacity_alias_fans_into_push_pull():
    spec = rg.get_scheme("balanced")
    kw = rg.stage_kwargs(spec, StageArgs(capacity=128))
    assert kw == {"cap_push": 128, "cap_pull": 128}
    kw = rg.stage_kwargs(spec, StageArgs(capacity=128, cap_pull=512))
    assert kw == {"cap_push": 128, "cap_pull": 512}


def test_cli_choices_derive_from_registry():
    choices = rg.cli_scheme_choices()
    assert "balanced" in choices and "auto" in choices
    # every executable scheme is offered; analytic-only curves are not
    assert "lower_bound" not in choices
    assert set(rg.registered_schemes(executable_only=True)) <= set(choices)


def test_plan_candidates_dense_first_balanced_last():
    cands = rg.plan_candidates()
    assert cands[0] == "dense"          # argmin ties resolve toward dense
    assert cands[-1] == "balanced"      # newcomer cannot steal exact ties
    assert "sparse_ps" not in cands and "omnireduce" not in cands


def test_registry_coverage_is_clean():
    assert rg.coverage_errors(TESTS_DIR) == []


def test_plan_stage_args_skips_size_one_levels():
    topo = tp.build_topology(8, 8)      # inter level has size 1
    plan = tp.resolve_plan("balanced", topo)
    kw = schemes.plan_stage_args(plan, topo, M, density_budget=0.25)
    assert 0 in kw and 1 not in kw
    assert kw[0].capacity == max(64, int(M * 0.25))

"""zenlint: IR parsing, rule catalog, AST rules, and golden fixtures.

The golden known-bad HLO fixtures (tests/fixtures/hlo/) each violate
exactly one paper invariant and must be flagged by exactly that rule —
a rule that fires on its neighbor's fixture is over-matching, one that
misses its own is dead.  The IR tests pin the two parsing fixes over the
old hlo_cost walker (nested-tuple results, async start/done pairs).
"""
import os
import textwrap

import pytest

from repro.analysis import ast_rules, hlo_ir, rules
from repro.analysis.hlo_ir import HloModule

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXDIR = os.path.join(ROOT, "tests", "fixtures", "hlo")


def _fixture(name: str) -> str:
    with open(os.path.join(FIXDIR, name)) as f:
        return f.read()


# ---------------------------------------------------------------------------
# golden fixtures: each bad module trips exactly its intended rule
# ---------------------------------------------------------------------------

def _subject(name: str) -> rules.Subject:
    text = _fixture(name)
    if name == "bad_fence.txt":  # StableHLO with the pipeline fences gone
        return rules.Subject(label=name, stablehlo_text=text,
                             expected_fences=2)
    return rules.Subject(label=name, module=HloModule.parse(text),
                         stablehlo_text=text)


@pytest.mark.parametrize("name,want", [
    ("clean.txt", set()),
    ("bad_sort.txt", {"R1"}),
    ("bad_f64.txt", {"R3"}),
    ("bad_fence.txt", {"R4"}),
    ("bad_while.txt", {"R5"}),
])
def test_fixture_flags_exactly_intended_rule(name, want):
    findings = rules.run_rules(_subject(name))
    got = {f.rule for f in findings}
    assert got == want, [str(f) for f in findings]


def test_fences_present_passes():
    text = _fixture("bad_fence.txt").replace(
        "return %3", "%4 = stablehlo.optimization_barrier %3 : "
                     "tensor<64xf32>\n    return %4")
    s = rules.Subject(label="fenced", stablehlo_text=text,
                      expected_fences=1)
    assert rules.run_rules(s) == []


def test_lint_exempt_waives_rule():
    s = _subject("bad_sort.txt")
    s.exempt = ("R1",)
    assert rules.run_rules(s) == []


# ---------------------------------------------------------------------------
# IR: nested tuples, async pairs, replica groups, trip weighting
# ---------------------------------------------------------------------------

PAIR_HLO = textwrap.dedent("""\
    HloModule pair

    %add (x: f32[], y: f32[]) -> f32[] {
      %x = f32[] parameter(0)
      %y = f32[] parameter(1)
      ROOT %s = f32[] add(%x, %y)
    }

    ENTRY %main (arg: f32[1024]) -> f32[1024] {
      %arg = f32[1024]{0} parameter(0)
      %st = (f32[1024]{0}, f32[1024]{0}) all-reduce-start(%arg), replica_groups={{0,1,2,3},{4,5,6,7}}, use_global_device_ids=true, to_apply=%add
      ROOT %dn = f32[1024]{0} all-reduce-done(%st)
    }
""")


def test_async_pair_counted_once():
    mod = HloModule.parse(PAIR_HLO)
    assert hlo_ir.count_collectives(mod) == 1
    wire = hlo_ir.collective_wire(mod)
    # one start/done pair: 4 KiB payload, ring factor 2(g-1)/g at g=4
    assert wire == {("all-reduce", 4): pytest.approx(2 * 3 / 4 * 4096)}


def test_hlo_cost_analyze_counts_pair_once():
    from repro.launch import hlo_cost
    walked = hlo_cost.analyze(PAIR_HLO)
    assert walked["collective_bytes_total"] == pytest.approx(
        2 * 3 / 4 * 4096)
    assert walked["collectives"] == {
        "all-reduce": pytest.approx(2 * 3 / 4 * 4096)}


def test_nested_tuple_result_not_skipped():
    line = ("  %st = ((f32[8]{0}), f32[8]{0}, u32[]) "
            "all-reduce-start(%a), replica_groups={{0,1}}, to_apply=%add")
    parsed = hlo_ir.split_op_line(line)
    assert parsed is not None
    name, shape, kind, _rest = parsed
    assert (name, kind) == ("st", "all-reduce-start")
    assert len(hlo_ir.tuple_elements(shape)) == 3
    op = hlo_ir.HloOp(*parsed)
    # scalar u32 context dropped, then second half of (operand, result)
    assert op.wire_data_bytes == 32


def test_group_size_forms():
    def mk(rest):
        return hlo_ir.HloOp("x", "f32[8]", "all-gather", rest)
    assert mk("%a), replica_groups={{0,1,2,3},{4,5,6,7}}").group_size == 4
    assert mk("%a), replica_groups=[2,4]<=[8]").group_size == 4
    assert mk("%a), dimensions={0}").group_size is None


def test_trip_weighted_collective_wire():
    text = _fixture("clean.txt").replace(
        "%vv = f32[64]{0} multiply(%v, %v)",
        "%vv = f32[64]{0} all-reduce(%v), replica_groups={{0,1}}, "
        "use_global_device_ids=true, to_apply=%add")
    wire = hlo_ir.collective_wire(HloModule.parse(text))
    # entry all-reduce once + loop-body all-reduce x trip_count 4
    assert wire == {("all-reduce", 2): pytest.approx(5 * 1.0 * 256)}


def test_find_sorts_both_dialects():
    assert rules.find_sorts(_fixture("bad_sort.txt"))
    assert rules.find_sorts('  %0 = "stablehlo.sort"(%arg0) ...')
    assert not rules.find_sorts(_fixture("clean.txt"))


# ---------------------------------------------------------------------------
# R4 dependence check on real jaxprs
# ---------------------------------------------------------------------------

def test_fence_dependence_on_jaxpr():
    import jax
    from jax import lax

    def bad(x):
        return lax.optimization_barrier(lax.psum(x, "i"))

    def good(x):
        return lax.psum(lax.optimization_barrier(x), "i")

    def mk(f):
        return jax.make_jaxpr(f, axis_env=[("i", 2)])(1.0)
    assert rules.fence_dependence_findings(mk(bad))
    assert not rules.fence_dependence_findings(mk(good))


# ---------------------------------------------------------------------------
# AST rules on synthetic sources + the live tree
# ---------------------------------------------------------------------------

def _ast(src: str, relpath: str = "src/repro/train/foo.py"):
    return {f.rule for f in ast_rules.check_source(
        textwrap.dedent(src), relpath)}


def test_ast1_raw_collective():
    src = "def f(x):\n    return lax.psum(x, 'data')\n"
    assert _ast(src) == {"AST1"}
    assert _ast(src, "src/repro/core/schemes.py") == set()
    assert _ast(src, "src/repro/kernels/foo.py") == set()


def test_ast1_mesh_structure_axes_exempt():
    assert _ast("def f(self, y):\n"
                "    return lax.pmax(y, self.tp_axis)\n") == set()
    assert _ast("def f(self, y):\n"
                "    return lax.pmean(y, axis_name=self.pod_axis)\n") == set()


def test_ast1_waiver_comment():
    assert _ast("def f(x):\n"
                "    return lax.psum(x, 'data')  "
                "# zenlint: ignore[AST1]\n") == set()


def test_ast2_scheme_literal_dispatch():
    assert _ast("def f(scheme):\n    return scheme == 'zen'\n") == {"AST2"}
    assert _ast("def f(scheme):\n    return scheme == 'dense'\n") == {"AST2"}
    # "dense" as an architecture kind is not a scheme comparison
    assert _ast("def f(cfg):\n    return cfg.kind == 'dense'\n") == set()
    assert _ast("def f(scheme):\n    return scheme == 'zen'\n",
                "src/repro/core/registry.py") == set()


def test_ast3_hardcoded_choices():
    assert _ast("p.add_argument('--sync', choices=['zen', 'dense'])\n"
                ) == {"AST3"}
    assert _ast("p.add_argument('--log', choices=['info', 'debug'])\n"
                ) == set()


def test_live_tree_is_clean(monkeypatch):
    monkeypatch.chdir(ROOT)
    findings = ast_rules.run_tree("src/repro")
    assert findings == [], [str(f) for f in findings]


# ---------------------------------------------------------------------------
# registry lint metadata: the wire contract is complete
# ---------------------------------------------------------------------------

def test_every_executable_scheme_has_wire_contract():
    from repro.core import registry as sreg
    for name in sreg.registered_schemes(executable_only=True):
        spec = sreg.get_scheme(name)
        assert spec.wire_words_fn is not None, name
        assert spec.expected_collectives, name
        assert spec.lint_caps_fn is not None or "layout" in spec.stage_args, \
            f"{name}: no lint_caps_fn and no layout-driven capacity"


def test_dense_wire_formula():
    from repro.core import registry as sreg
    spec = sreg.get_scheme("dense")
    assert spec.wire_words_fn(4096, 8, {}) == pytest.approx(
        2 * 7 / 8 * 4096)
    assert spec.wire_words_fn(4096, 2, {}) == pytest.approx(4096)

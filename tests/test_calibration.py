"""Measured-time calibration tests (DESIGN.md §11).

Three contracts:

1. **Identity degeneracy** (property-tested): ``choose_scheme`` /
   ``choose_plan`` with ``CalibrationTable.identity()`` are *bitwise*
   identical to the analytic α-β decision — over random profiles, int-n,
   flat and two-level topologies — preserving PR 5's flat/hier
   invariants (tests/test_topology.py).
2. **Encode overhead is one-directional**: a measured table can only
   flip zen -> dense (dense encodes for free), never dense -> zen, and
   a synthetic encode-dominant table *does* flip every zen pick.
3. **Persistence**: save/load round-trips exactly, version mismatches
   are rejected, and CostCalibrator produces a loadable table (the CI
   ``calibration-smoke`` step exercises the CLI end-to-end).
"""
import json
import math

import pytest

from hypothesis_compat import given, settings, st

from repro.core import costmodel as cm
from repro.core import topology as tp


def _profile(m_log2: int, d1: float, gamma: float, skew: float):
    M = 1 << m_log2
    block = 256

    def d(i):
        return min(1.0, d1 * max(i, 1) ** gamma)

    def s(k):
        return 1.0 + skew * math.log2(max(k, 1))

    return cm.SparsityProfile(
        M=M, d=d, s=s, block=block,
        block_density=lambda i: min(1.0, d(i) * block),
        block_max=lambda i, parts: min(1.0, d(i) * block * s(parts)),
    )


PROFILE_ST = st.tuples(
    st.integers(10, 22),                            # log2 M
    st.floats(1e-4, 0.9),                           # d(1)
    st.floats(0.05, 1.0),                           # densification exponent
    st.floats(0.0, 2.0),                            # skew growth
)


def _synthetic_table(encode_us: float = 1e9, *, n: int = 8,
                     size: int = 1 << 14, density: float = 0.01,
                     dense_us: float = 100.0) -> cm.CalibrationTable:
    """One-entry table with the full entry-key schema; the default
    encode_us dwarfs any wire term (the encode-dominant CI fixture)."""
    return cm.CalibrationTable(entries=[dict(
        backend="xla", size=size, density=density, n=n,
        encode_us=encode_us, commit_us=0.0,
        zen_us=encode_us, dense_us=dense_us)])


# ---------------------------------------------------------------------------
# 1. identity degeneracy (the property the ISSUE names)
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=30)
@given(PROFILE_ST, st.sampled_from([2, 4, 8, 16, 64]))
def test_identity_degenerates_int_and_flat(args, n):
    p = _profile(*args)
    ident = cm.CalibrationTable.identity()
    assert cm.choose_scheme(p, n, calib=ident) == cm.choose_scheme(p, n)
    topo = tp.flat_topology(n)
    assert (cm.choose_scheme(p, topo, calib=ident)
            == cm.choose_scheme(p, topo))


@settings(deadline=None, max_examples=30)
@given(PROFILE_ST, st.sampled_from([(2, 2), (2, 4), (4, 2), (8, 4)]))
def test_identity_degenerates_hier_to_analytic_argmin(args, shape):
    """Measured-time choose_plan with the identity table IS the analytic
    α-β argmin: same plan object, and that plan attains the published
    plan_times minimum (PR 5's invariant, now under the calib path)."""
    p = _profile(*args)
    topo = tp.two_level_topology(*shape)
    ident = cm.CalibrationTable.identity()
    analytic = cm.choose_plan(p, topo)
    measured = cm.choose_plan(p, topo, calib=ident)
    assert measured.tag() == analytic.tag()
    times = cm.plan_times(p, topo)
    times.pop("lower_bound")
    # threshold=1.0 biases ties toward dense; the picked plan still must
    # attain the minimum of the published candidate times
    assert times[measured.tag()] <= min(times.values()) * (1 + 1e-12)


@settings(deadline=None, max_examples=30)
@given(PROFILE_ST, st.sampled_from([2, 4, 8, 16]))
def test_identity_preserves_flat_hier_bit_identity(args, n):
    """PR 5's degenerate-topology invariant survives the calib path: the
    flat Topology and the historical int-n signature still agree exactly
    when the identity table is threaded through."""
    p = _profile(*args)
    topo = tp.flat_topology(n)
    ident = cm.CalibrationTable.identity()
    assert (cm.choose_scheme(p, topo, calib=ident)
            == cm.choose_scheme(p, n, calib=ident))


def test_identity_plan_encode_overhead_is_zero():
    p = cm.worst_case_profile(1 << 12, 0.05)
    topo = tp.two_level_topology(4, 2)
    ident = cm.CalibrationTable.identity()
    for plan in cm.candidate_plans(topo, p.M):
        assert cm.plan_encode_overhead(ident, plan, p, topo) == 0.0


# ---------------------------------------------------------------------------
# 2. encode overhead flips zen -> dense, never the reverse
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=40)
@given(PROFILE_ST, st.sampled_from([2, 4, 8]),
       st.floats(0.0, 1e7))
def test_encode_overhead_never_flips_dense_to_zen(args, n, enc):
    p = _profile(*args)
    table = _synthetic_table(enc, n=n, size=p.M * p.vw, density=p.d(1))
    if cm.choose_scheme(p, n) == "dense":
        assert cm.choose_scheme(p, n, calib=table) == "dense"


def test_encode_dominant_table_flips_flat_to_dense():
    """The CI calibration-smoke fixture: a profile the analytic model
    confidently gives to zen flips to dense once encode costs 1e9 µs."""
    p = cm.worst_case_profile(1 << 14, 0.01)
    n = 8
    assert cm.choose_scheme(p, n) == "zen"
    table = _synthetic_table()
    assert cm.choose_scheme(p, n, calib=table) == "dense"
    topo = tp.flat_topology(n)
    assert cm.choose_scheme(p, topo) == "zen"
    assert cm.choose_scheme(p, topo, calib=table) == "dense"


def test_encode_dominant_table_prices_zen_plans_out_hier():
    """Only zen pays measured encode (the table prices dense and other
    schemes' encodes at 0), so under an encode-dominant table every
    zen-bearing candidate must time worse than all-dense and the chosen
    plan must carry no zen stage."""
    p = cm.worst_case_profile(1 << 14, 0.01)
    topo = tp.two_level_topology(4, 2)
    table = _synthetic_table()
    cands = cm.candidate_plans(topo, p.M)
    dense_t = cm.plan_time(cands[0], p, topo)
    for plan in cands:
        if not any(s.scheme == "zen" for s in plan.stages):
            continue
        t = (cm.plan_time(plan, p, topo)
             + cm.plan_encode_overhead(table, plan, p, topo))
        assert t > dense_t, plan.tag()
    measured = cm.choose_plan(p, topo, calib=table)
    assert all(s.scheme != "zen" for s in measured.stages), measured.tag()


def test_encode_us_lookup_scales_linearly_and_dense_is_free():
    table = _synthetic_table(100.0, size=1 << 10)
    assert table.encode_us("dense", 1 << 10, 0.01) == 0.0
    assert table.encode_us("zen", 1 << 10, 0.01) == 100.0
    assert table.encode_us("zen", 1 << 11, 0.01) == pytest.approx(200.0)
    ident = cm.CalibrationTable.identity()
    assert ident.encode_us("zen", 1 << 20, 0.01) == 0.0
    assert ident.beta_us_per_word(1 << 20) == 1.0


def test_commit_us_lookup_mirrors_encode_us():
    """commit_us prices like encode_us: zen-only, log-nearest entry,
    linear in size, 0 on the identity table (degeneracy preserved)."""
    table = cm.CalibrationTable(entries=[dict(
        backend="xla", size=1 << 10, density=0.01, n=4,
        encode_us=10.0, commit_us=40.0, zen_us=60.0, dense_us=50.0)])
    assert table.commit_us("dense", 1 << 10, 0.01) == 0.0
    assert table.commit_us("zen", 1 << 10, 0.01) == 40.0
    assert table.commit_us("zen", 1 << 11, 0.01) == pytest.approx(80.0)
    ident = cm.CalibrationTable.identity()
    assert ident.commit_us("zen", 1 << 20, 0.01) == 0.0


def test_nearest_lookup_prefers_closest_log_point():
    table = cm.CalibrationTable(entries=[
        dict(backend="xla", size=1 << 10, density=0.01, n=4,
             encode_us=10.0, commit_us=0.0, zen_us=10.0, dense_us=50.0),
        dict(backend="xla", size=1 << 16, density=0.01, n=4,
             encode_us=640.0, commit_us=0.0, zen_us=640.0, dense_us=70.0),
    ])
    # exact hits return the entry's own encode time
    assert table.encode_us("zen", 1 << 10, 0.01) == 10.0
    assert table.encode_us("zen", 1 << 16, 0.01) == 640.0
    # off-grid sizes pick the log-nearest entry and scale linearly
    assert table.encode_us("zen", 1 << 11, 0.01) == pytest.approx(20.0)
    assert table.encode_us("zen", 1 << 15, 0.01) == pytest.approx(320.0)


# ---------------------------------------------------------------------------
# 3. persistence + calibrator smoke
# ---------------------------------------------------------------------------

def test_json_round_trip(tmp_path):
    table = _synthetic_table(123.5)
    table.meta = {"backend": "xla", "host": "ci"}
    path = tmp_path / "calib.json"
    table.save(path)
    loaded = cm.CalibrationTable.load(path)
    assert loaded.entries == table.entries
    assert loaded.meta == table.meta


def test_version_mismatch_rejected(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text(json.dumps({"version": 999, "entries": []}))
    with pytest.raises(ValueError, match="version"):
        cm.CalibrationTable.load(path)
    # v1 tables carried the clamped-residual commit_us — semantically
    # different numbers under the same key, so they must be rejected too
    # (not silently reinterpreted as direct measurements)
    assert cm._CALIB_VERSION == 2
    path.write_text(json.dumps({"version": 1, "entries": []}))
    with pytest.raises(ValueError, match="version"):
        cm.CalibrationTable.load(path)


def test_cost_calibrator_measures_and_round_trips(tmp_path):
    cal = cm.CostCalibrator(n=2, sizes=(1024,), densities=(0.05,),
                            iters=1, warmup=1)
    table = cal.measure()
    assert len(table.entries) == 1
    e = table.entries[0]
    for key in ("backend", "size", "density", "n",
                "encode_us", "commit_us", "zen_us", "dense_us"):
        assert key in e, key
    assert e["encode_us"] > 0.0
    assert e["dense_us"] > 0.0
    # v2: commit_us is a direct measurement of a real jitted zen_commit
    # run — unlike the v1 clamped residual it can never be exactly 0
    assert e["commit_us"] > 0.0
    path = tmp_path / "measured.json"
    table.save(path)
    assert cm.CalibrationTable.load(path).entries == table.entries


def test_cost_calibrator_rejects_degenerate_axis():
    with pytest.raises(ValueError):
        cm.CostCalibrator(n=1)

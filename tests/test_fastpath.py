"""The sort-free, Pallas-backed Zen fast path.

Three properties the perf work must not break:
  * zen_sync lowers with NO ``sort`` op on either backend (the O(C log C)
    argsort/searchsorted ranking is gone for good — asserted on the HLO);
  * backend="pallas" (interpret) is bit-exact with backend="xla";
  * the sort-free compaction / serial ranking agree with the old
    argsort-based references on random inputs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metrics, schemes
from repro.core.hashing import (
    EMPTY,
    compact_rows,
    hierarchical_hash,
    make_seeds,
    partition_rank,
    row_compact,
)
from repro.kernels import ops, ref


def _dyadic_workers(seed, n, m, density, d=None):
    """Worker gradients whose values are small dyadic rationals: float sums
    over them are exact, so scatter-add accumulation order cannot perturb
    results and bit-exact cross-backend comparison is meaningful."""
    key = jax.random.PRNGKey(seed)
    masks = metrics.synth_sparse_masks(key, n, m, density)
    shape = (n, m) if d is None else (n, m, d)
    vals = jnp.round(jax.random.normal(key, shape) * 256) / 256
    return vals * (masks if d is None else masks[..., None])


# ---------------------------------------------------------------------------
# backend parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("density", [0.02, 0.1, 0.3])
@pytest.mark.parametrize("n,d", [(2, None), (4, None), (4, 8)])
def test_zen_backend_parity_bit_exact(n, d, density):
    m = 2048
    vals = _dyadic_workers(0, n, m, density, d)
    layout = schemes.make_zen_layout(m, n, density_budget=min(0.5, 4 * density))
    out_x, st_x = schemes.simulate(schemes.zen_sync, vals, layout=layout,
                                   backend="xla")
    out_p, st_p = schemes.simulate(schemes.zen_sync, vals, layout=layout,
                                   backend="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(out_x), np.asarray(out_p))
    np.testing.assert_array_equal(np.asarray(st_x.sent_words),
                                  np.asarray(st_p.sent_words))
    np.testing.assert_array_equal(np.asarray(st_x.overflow),
                                  np.asarray(st_p.overflow))
    # and both match the psum oracle
    np.testing.assert_allclose(np.asarray(out_x)[0],
                               np.asarray(vals.sum(0)), atol=1e-4)


def test_hierarchical_hash_backend_parity():
    rng = np.random.default_rng(0)
    cap, n, r1, r2, k = 1024, 8, 256, 32, 3
    pick = rng.choice(100_000, size=700, replace=False)
    idx = np.full(cap, EMPTY, np.int32)
    idx[:700] = np.sort(pick)
    idx = jnp.asarray(idx)
    seeds = np.asarray(make_seeds(3, k + 1))
    part_x = hierarchical_hash(idx, n=n, r1=r1, r2=r2, k=k,
                               seeds=jnp.asarray(seeds))
    part_p = hierarchical_hash(idx, n=n, r1=r1, r2=r2, k=k, backend="pallas",
                               interpret=True,
                               static_seeds=tuple(int(s) for s in seeds))
    np.testing.assert_array_equal(np.asarray(part_x.memory),
                                  np.asarray(part_p.memory))
    np.testing.assert_array_equal(np.asarray(part_x.rounds_used),
                                  np.asarray(part_p.rounds_used))
    assert int(part_x.overflow) == int(part_p.overflow)


# ---------------------------------------------------------------------------
# no sort in the lowered HLO (both backends)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_zen_sync_hlo_contains_no_sort(backend):
    # same check as zenlint rule R1 (repro.analysis.rules) — shared here
    # so the assertion and the CI gate can never drift apart
    from repro.analysis.rules import find_sorts

    n, m = 4, 2048
    layout = schemes.make_zen_layout(m, n, density_budget=0.2)
    fn = jax.jit(lambda v: schemes.simulate(
        schemes.zen_sync, v, layout=layout, backend=backend, interpret=True))
    x = jnp.zeros((n, m))
    for text in (fn.lower(x).as_text(), fn.lower(x).compile().as_text()):
        assert not find_sorts(text), (
            f"{backend} zen_sync HLO contains a sort op: {find_sorts(text)}")


# ---------------------------------------------------------------------------
# sort-free compaction / ranking vs the argsort references
# ---------------------------------------------------------------------------

def _random_memory(rng, rows, cols, fill):
    mem = rng.integers(0, 1 << 20, size=(rows, cols)).astype(np.int32)
    mem[rng.uniform(size=mem.shape) > fill] = EMPTY
    return jnp.asarray(mem)


@pytest.mark.parametrize("seed", range(5))
def test_row_compact_equals_argsort_reference(seed):
    rng = np.random.default_rng(seed)
    mem = _random_memory(rng, rows=16, cols=200, fill=0.4)
    for got in (row_compact(mem), ops.row_compact_op(mem)):
        got = np.asarray(got)
        want = np.asarray(ref.row_compact_argsort_ref(mem))
        # same EMPTY-padding structure...
        np.testing.assert_array_equal(got == EMPTY, want == EMPTY)
        # ...and per-row the same live values (sort-free preserves slot
        # order; the argsort reference sorts them ascending)
        np.testing.assert_array_equal(np.sort(got, axis=1), want)


def test_row_compact_preserves_slot_order():
    mem = jnp.asarray([[EMPTY, 7, EMPTY, 3, 9, EMPTY]], jnp.int32)
    want = [7, 3, 9, EMPTY, EMPTY, EMPTY]
    np.testing.assert_array_equal(np.asarray(row_compact(mem))[0], want)
    np.testing.assert_array_equal(np.asarray(ops.row_compact_op(mem))[0], want)


def _rank_argsort_ref(p, surv, n):
    """The pre-fast-path serial-memory ranking (stable argsort +
    searchsorted), kept verbatim as the equivalence oracle."""
    psurv = jnp.where(surv, p, n)
    order = jnp.argsort(psurv, stable=True)
    p_sorted = psurv[order]
    idx_in_run = jnp.arange(p.shape[0]) - jnp.searchsorted(
        p_sorted, p_sorted, side="left")
    return jnp.full_like(p, -1).at[order].set(idx_in_run)


@pytest.mark.parametrize("seed", range(5))
def test_partition_rank_equals_argsort_reference(seed):
    rng = np.random.default_rng(seed)
    C, n = 777, 16
    p = jnp.asarray(rng.integers(0, n, size=C).astype(np.int32))
    surv = jnp.asarray(rng.uniform(size=C) < 0.3)
    got = np.asarray(partition_rank(p, surv, n))
    want = np.asarray(_rank_argsort_ref(p, surv, n))
    s = np.asarray(surv)
    # ranks must agree wherever they matter (survivors); dead entries are -1
    # in the sort-free version and arbitrary in the argsort reference
    np.testing.assert_array_equal(got[s], want[s])
    assert (got[~s] == -1).all()


def test_compact_rows_matches_per_row_compact_indices():
    from repro.core.hashing import compact_indices

    rng = np.random.default_rng(1)
    mask = jnp.asarray(rng.uniform(size=(6, 500)) < 0.25)
    cap = 96
    out, ov = compact_rows(mask, cap)
    for i in range(mask.shape[0]):
        want, wov = compact_indices(mask[i], cap)
        np.testing.assert_array_equal(np.asarray(out[i]), np.asarray(want))
        assert int(ov[i]) == int(wov)


# ---------------------------------------------------------------------------
# layout device tables
# ---------------------------------------------------------------------------

def test_zen_layout_device_tables_cached():
    layout = schemes.make_zen_layout(4096, 4, density_budget=0.1)
    t1 = layout.device_tables()
    t2 = layout.device_tables()
    assert t1 is t2  # uploaded once, reused across traces
    np.testing.assert_array_equal(np.asarray(t1.perm), layout.perm)
    np.testing.assert_array_equal(np.asarray(t1.local_pos), layout.local_pos)
    np.testing.assert_array_equal(np.asarray(t1.offsets), layout.offsets)

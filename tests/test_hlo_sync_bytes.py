"""SyncStats wire-byte accounting vs what XLA actually moves.

``SyncStats.sent_words`` is the number the cost model, the benchmarks,
and the regression gate all reason about — if it drifts from the bytes
the compiled collectives move, every downstream claim is fiction.  This
test lowers real schemes under ``shard_map`` on an 8-device host mesh
(subprocess, same pattern as test_multidevice) and diffs the claimed
words*4 against ``launch/hlo_cost.py``'s trip-weighted collective bytes:

  * dense: psum of M f32 -> all-reduce wire 2(g-1)/g * 4M bytes, and the
    claim is exact by construction;
  * agsparse: two all_gathers (i32 idx + f32 val) -> (g-1) * 8C bytes.
    The claim counts actual non-zeros while XLA moves full capacity, so
    the payload here saturates capacity exactly (nnz == C) and the
    comparison is exact — any static-shape or factor drift fails;
  * balanced: the stride-16 payload makes every histogram bin hold
    exactly one entry per worker, so the rebalanced ranges give every
    worker C/8 entries per destination (cap_push saturated), C distinct
    indices per reduced shard (cap_pull saturated), and the three
    collectives (histogram all-reduce, COO all-to-all, shard
    all-gather) are each byte-exact against the claim.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

WORKER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core import schemes
    from repro.launch import hlo_cost
    from repro.launch.mesh import make_mesh

    N, M, C = 8, 4096, 256
    mesh = make_mesh((8,), ("data",))
    try:
        sm = jax.shard_map
        smkw = dict(check_vma=False)
    except AttributeError:
        from jax.experimental.shard_map import shard_map as sm
        smkw = dict(check_rep=False)

    # every worker holds EXACTLY C non-zeros (distinct positions, dyadic
    # values): sparse claims then equal capacity-shaped wire traffic
    g = np.zeros((N, M), np.float32)
    for i in range(N):
        pos = (np.arange(C) * 16 + i) % M
        g[i, pos] = 1.0 + i / 8.0
    g = jnp.asarray(g)

    def measure(fn, **kw):
        def local(v):
            out, st = fn(v[0], axis="data", **kw)
            return out, st.sent_words[None], st.overflow[None]
        mapped = sm(local, mesh=mesh, in_specs=P("data"),
                    out_specs=(P(), P("data"), P("data")), **smkw)
        jfn = jax.jit(mapped)
        out, words, ov = jfn(g)
        assert int(np.asarray(ov).sum()) == 0, "capacity violated"
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(g).sum(0), atol=1e-5)
        hlo = jfn.lower(g).compile().as_text()
        walked = hlo_cost.analyze(hlo)
        # per-device claim (workers are symmetric here)
        claim = float(np.asarray(words).reshape(-1)[0]) * 4.0
        return claim, float(walked["collective_bytes_total"]), walked

    c, m, w = measure(schemes.dense_sync)
    assert abs(c - m) < 1e-6 * max(c, 1), (
        "dense: SyncStats %.1fB vs XLA %.1fB (%s)" % (c, m, w))
    print("DENSE_BYTES", c, m)

    c, m, w = measure(schemes.agsparse_sync, capacity=C)
    assert abs(c - m) < 1e-6 * max(c, 1), (
        "agsparse: SyncStats %.1fB vs XLA %.1fB (%s)" % (c, m, w))
    print("AGSPARSE_BYTES", c, m)

    # balanced: cap_push = C/8 per-destination slots (the stride-16
    # payload rebalances to exactly C/8 entries per (worker, dest)),
    # cap_pull = C distinct indices per reduced range — both saturated,
    # so claim == wire exactly across all three collectives
    c, m, w = measure(schemes.balanced_sync, n=N, cap_push=C // 8,
                      cap_pull=C)
    assert abs(c - m) < 1e-6 * max(c, 1), (
        "balanced: SyncStats %.1fB vs XLA %.1fB (%s)" % (c, m, w))
    print("BALANCED_BYTES", c, m)
    print("ALL_OK")
""")


@pytest.mark.slow
def test_sync_stats_match_hlo_collective_bytes():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", WORKER], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "ALL_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-4000:]

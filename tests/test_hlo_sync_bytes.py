"""SyncStats wire-byte accounting vs what XLA actually moves.

``SyncStats.sent_words`` is the number the cost model, the benchmarks,
and the regression gate all reason about — if it drifts from the bytes
the compiled collectives move, every downstream claim is fiction.

This used to be a hand-rolled three-scheme comparison; it is now a thin
wrapper over zenlint's R2 rule (``repro.analysis``), which lowers every
scheme under ``shard_map`` on the 8-device host mesh, measures the
trip-weighted collective bytes per replica-group size off the optimized
HLO, and diffs them against the registry's ``wire_words_fn`` contract
AND the program's own SyncStats claim (exact for saturable schemes).
The subset here keeps the original coverage (dense / agsparse /
balanced, flat and hierarchical at n=8) at tier-1-friendly cost; the
full sweep — every scheme, n in {2, 8}, plus the run_schedule subject —
is ``make check-hlo``.
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_sync_stats_match_hlo_collective_bytes():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", "--hlo-only",
         "--schemes", "dense,agsparse,balanced", "--ns", "8"],
        env=env, cwd=ROOT, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-4000:]
    assert "0 finding(s)" in r.stdout, r.stdout[-3000:]

"""Error-feedback sparsification stack (DESIGN.md §8).

The contracts that make induced sparsity safe to train with:
  * the EF invariant — sent + residual' == grad + residual, exactly;
  * bit-exact determinism under jit, identity under vmap (no cross-worker
    leakage through the residual);
  * residual state survives a checkpoint round-trip through
    ``checkpoint/io.py`` bit-exactly;
  * convergence: top-k WITH error feedback converges on a toy quadratic
    where plain top-k provably stalls (worker-wise cancellation);
  * the adaptive density controller flips dense<->zen from MEASURED
    densities, per bucket.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import buckets as bk
from repro.core import sparsify
from repro.core.sparsify import (
    CompressConfig,
    DensityController,
    compress_bucket,
    parse_compress,
)
from repro.core.zen import GradSync, SyncConfig
from repro.checkpoint import io as ckpt_io

N = 4


# ---------------------------------------------------------------------------
# config parsing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec, kind, ef", [
    ("topk:0.01", "topk", True),
    ("randk:0.05", "randk", True),
    ("topk:0.02:noef", "topk", False),
    ("threshold:1e-3", "threshold", True),
    ("none", "none", True),
])
def test_parse_compress(spec, kind, ef):
    cfg = parse_compress(spec)
    assert cfg.kind == kind and cfg.ef == ef
    # tag() round-trips through the parser (the bucket plan stores tags)
    assert parse_compress(cfg.tag()) == cfg


@pytest.mark.parametrize("bad", ["topk", "topk:0", "topk:2.0", "magic:0.1",
                                 "topk:0.1:what"])
def test_parse_compress_rejects(bad):
    with pytest.raises(ValueError):
        parse_compress(bad)


# ---------------------------------------------------------------------------
# the sparsifiers + EF invariant
# ---------------------------------------------------------------------------

def _payload(size=512, seed=0, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), (size,)).astype(dtype)


def test_topk_keeps_exactly_k():
    cfg = CompressConfig(kind="topk", density=0.05)
    g = _payload(400)
    sent, res, d1 = compress_bucket(cfg, g, jnp.zeros(400))
    k = cfg.keep_count(400)
    assert int(jnp.sum(sent != 0)) == k
    assert float(d1) == pytest.approx(k / 400)
    # the kept elements are the largest-|g| ones
    kept = np.flatnonzero(np.asarray(sent))
    top = np.argsort(-np.abs(np.asarray(g)))[:k]
    assert set(kept) == set(top)


@pytest.mark.parametrize("kind", ["topk", "threshold", "randk"])
def test_ef_invariant_exact(kind):
    """sent + residual' == payload + residual in f32, bit-exact: EF moves
    information, never loses it."""
    cfg = CompressConfig(kind=kind, density=0.1, threshold=0.5)
    g = _payload(300, seed=1)
    r = _payload(300, seed=2) * 0.1
    key = jax.random.PRNGKey(7)
    sent, r2, _ = compress_bucket(cfg, g, r, key=key)
    np.testing.assert_array_equal(
        np.asarray(sent.astype(jnp.float32) + r2), np.asarray(g + r))


def test_ef_invariant_bf16_payload():
    """With a bf16 payload the residual must compensate against the CAST
    wire values, so the f32 invariant still holds exactly."""
    cfg = CompressConfig(kind="topk", density=0.1)
    g = _payload(256, seed=3, dtype=jnp.bfloat16)
    r = _payload(256, seed=4) * 0.01
    sent, r2, _ = compress_bucket(cfg, g, r)
    assert sent.dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(sent.astype(jnp.float32) + r2),
        np.asarray(g.astype(jnp.float32) + r))


def test_jit_deterministic_and_matches_eager():
    cfg = CompressConfig(kind="topk", density=0.03)
    g, r = _payload(1024, seed=5), _payload(1024, seed=6) * 0.1
    jitted = jax.jit(lambda g_, r_: compress_bucket(cfg, g_, r_))
    a = jitted(g, r)
    b = jitted(g, r)
    c = compress_bucket(cfg, g, r)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(a, c):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_randk_deterministic_in_key():
    cfg = CompressConfig(kind="randk", density=0.2)
    g = _payload(512)
    k1, k2 = jax.random.PRNGKey(0), jax.random.PRNGKey(1)
    s1, _, _ = compress_bucket(cfg, g, None, key=k1)
    s1b, _, _ = compress_bucket(cfg, g, None, key=k1)
    s2, _, _ = compress_bucket(cfg, g, None, key=k2)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s1b))
    assert np.any(np.asarray(s1) != np.asarray(s2))


def test_vmap_is_identity_per_worker():
    """vmapped compression == stacked per-worker compression: the residual
    memory is strictly per-worker state, nothing leaks across the batch
    axis (the single-device worker-simulation contract)."""
    cfg = CompressConfig(kind="topk", density=0.06)
    gs = jnp.stack([_payload(200, seed=i) for i in range(N)])
    rs = jnp.stack([_payload(200, seed=10 + i) * 0.1 for i in range(N)])
    sent_v, res_v, d_v = jax.vmap(
        lambda g, r: compress_bucket(cfg, g, r))(gs, rs)
    for i in range(N):
        s_i, r_i, d_i = compress_bucket(cfg, gs[i], rs[i])
        np.testing.assert_array_equal(np.asarray(sent_v[i]), np.asarray(s_i))
        np.testing.assert_array_equal(np.asarray(res_v[i]), np.asarray(r_i))
        np.testing.assert_array_equal(np.asarray(d_v[i]), np.asarray(d_i))


# ---------------------------------------------------------------------------
# GradSync integration: plans, schemes, residual threading
# ---------------------------------------------------------------------------

def _tree_shapes(n_dense=24, dense_size=256, rows=256, d=8):
    return {
        "embed": {"table": jax.ShapeDtypeStruct((rows, d), jnp.float32)},
        "layers": {f"w{i:02d}": jax.ShapeDtypeStruct((dense_size,),
                                                     jnp.float32)
                   for i in range(n_dense)},
    }


def _tree_grads(shapes, density=0.1, seed=0):
    key = jax.random.PRNGKey(seed)

    def leaf(path, s):
        k = jax.random.fold_in(key, hash(bk.leaf_path_str(path)) % (1 << 30))
        g = jax.random.normal(k, (N, *s.shape))
        if "table" in bk.leaf_path_str(path):
            m = jax.random.uniform(k, (N, s.shape[0], 1)) < density
            g = g * m
        return g.astype(s.dtype)

    return jax.tree_util.tree_map_with_path(leaf, shapes)


def _make_gs(compress, scheme="auto", bucket_bytes=4096, n=N, shapes=None):
    return GradSync(
        SyncConfig(scheme=scheme, density_budget=0.25,
                   bucket_bytes=bucket_bytes, compress=compress),
        ["embed/table"], shapes or _tree_shapes(), n, data_axis="data")


def _vsync(gs, grads, residual):
    resb = {k: jnp.tile(v[None], (N,) + (1,) * v.ndim)
            for k, v in residual.items()}
    return jax.vmap(lambda g, r: gs(g, r, step=jnp.int32(0)),
                    axis_name="data")(grads, resb)


def test_plan_tags_compressed_dense_buckets_only():
    gs = _make_gs("topk:0.01")
    kinds = {(b.kind, b.compress) for b in gs.plan.buckets}
    for b in gs.plan.buckets:
        if b.kind == bk.SPARSE:
            assert b.compress == "none"
        else:
            assert b.compress == "topk:0.01"
    assert (bk.SPARSE, "none") in kinds
    gs.plan.validate()


def test_auto_flips_on_configured_density():
    """The offline decision: low keep-density -> zen, high -> dense (per
    compressed bucket, from compress_profile through choose_scheme)."""
    lo = _make_gs("topk:0.05", n=2)
    hi = _make_gs("topk:0.5", n=2)
    assert set(lo.bucket_schemes().values()) == {"zen"}
    assert set(hi.bucket_schemes().values()) == {"dense"}


def test_compressed_zen_equals_compressed_dense():
    """The wire scheme must not change WHAT is synchronized: zen on the
    sparsified payloads == psum of the sparsified payloads (Zen's
    no-information-loss claim, now on induced sparsity), and the EF
    residuals — computed before the wire — are bit-identical."""
    shapes = _tree_shapes()
    grads = _tree_grads(shapes)
    out = {}
    for scheme in ("zen", "dense"):
        gs = _make_gs("topk:0.02", scheme=scheme, shapes=shapes)
        synced, nres, stats = _vsync(gs, grads, gs.init_residual())
        out[scheme] = (synced, nres, stats)
    for a, b in zip(jax.tree.leaves(out["zen"][0]),
                    jax.tree.leaves(out["dense"][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    for a, b in zip(jax.tree.leaves(out["zen"][1]),
                    jax.tree.leaves(out["dense"][1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(np.asarray(out["zen"][2]["sync/overflow"]).sum()) == 0


def test_compressed_wire_volume_beats_dense():
    """topk:0.01 + zen must cut the dense buckets' wire volume by >=10x
    (the BENCH acceptance bar, asserted at unit level too)."""
    shapes = {"layers": {f"w{i:02d}": jax.ShapeDtypeStruct((1024,),
                                                           jnp.float32)
                         for i in range(16)}}
    gs = _make_gs("topk:0.01", shapes=shapes, bucket_bytes=1 << 14)
    grads = _tree_grads(shapes)
    assert set(gs.bucket_schemes().values()) == {"zen"}
    _, _, stats = _vsync(gs, grads, gs.init_residual())
    total = sum(p.size for p in jax.tree.leaves(shapes))
    dense_words = 2 * (N - 1) / N * total
    sent = float(np.asarray(stats["sync/sparse_sent_words"]).mean())
    assert float(np.asarray(stats["sync/dense_words"]).mean()) == 0.0
    assert sent < 0.10 * dense_words, (sent, dense_words)


def test_ef_requires_residual():
    gs = _make_gs("topk:0.01")
    with pytest.raises(ValueError, match="residual"):
        jax.vmap(gs, axis_name="data")(_tree_grads(_tree_shapes()))


def test_noef_keeps_no_state():
    gs = _make_gs("topk:0.01:noef")
    assert gs.init_residual() == {}
    synced, nres, stats = _vsync(gs, _tree_grads(_tree_shapes()), {})
    assert nres == {}
    assert "sync/compressed_buckets" in stats


def test_density_metrics_reported():
    gs = _make_gs("topk:0.02")
    _, _, stats = _vsync(gs, _tree_grads(_tree_shapes()), gs.init_residual())
    keys = [k for k in stats if k.startswith("sync/ef_density1")]
    keysN = [k for k in stats if k.startswith("sync/ef_densityN")]
    assert len(keys) == len(keysN) == len(gs.compressed_buckets())
    for k in keys:
        d1 = float(np.asarray(stats[k]).mean())
        assert 0 < d1 <= 0.05  # ~the configured keep-density
    for k in keysN:
        dn = float(np.asarray(stats[k]).mean())
        assert 0 < dn <= 4 * 0.05  # <= n * d1 by the union bound


# ---------------------------------------------------------------------------
# checkpoint round-trip (residual in optimizer state)
# ---------------------------------------------------------------------------

def test_residual_checkpoint_roundtrip(tmp_path):
    """One sync step's residual state survives save/restore through
    checkpoint/io.py bit-exactly, and a restarted trainer continues
    bit-identically to an uninterrupted one."""
    shapes = _tree_shapes(n_dense=8)
    grads = _tree_grads(shapes)
    gs = _make_gs("topk:0.05", shapes=shapes)
    _, res1, _ = _vsync(gs, grads, gs.init_residual())
    state = {"residual": res1, "step": jnp.int32(1)}
    ckpt_io.save(tmp_path / "ck", state)
    back = ckpt_io.restore(tmp_path / "ck")
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # continuing from the restored residual == continuing in-process
    res1_local = {k: v[0] for k, v in res1.items()}
    back_local = {k: v[0] for k, v in back["residual"].items()}
    grads2 = _tree_grads(shapes, seed=1)
    _, r_a, _ = _vsync(gs, grads2, res1_local)
    _, r_b, _ = _vsync(gs, grads2, back_local)
    for a, b in zip(jax.tree.leaves(r_a), jax.tree.leaves(r_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_opt_state_carries_residual():
    """steps.init_opt_state / opt_pspecs / abstract_opt_state agree on the
    residual entry: per-device f32, dim0 = devices * local payload."""
    from jax.sharding import PartitionSpec as P

    from repro.models.common import ShardCtx
    from repro.train import steps as st
    from repro.train.steps import TrainerConfig

    ctx = ShardCtx(tp=1, dp=1)
    tcfg = TrainerConfig(sync=SyncConfig(scheme="auto", compress="topk:0.1",
                                         bucket_bytes=4096))
    shapes = _tree_shapes(n_dense=4)
    gs = GradSync(tcfg.sync, ["embed/table"], shapes, 1, data_axis="data")
    params = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
    specs = jax.tree.map(lambda s: P(*([None] * len(s.shape))), shapes)
    opt = st.init_opt_state(tcfg, params, ctx, specs, gradsync=gs)
    pspecs = st.opt_pspecs(tcfg, specs, ctx, gradsync=gs)
    abstract = st.abstract_opt_state(tcfg, shapes, ctx, specs, gradsync=gs)
    want = gs.compressed_buckets()
    assert set(opt["residual"]) == set(pspecs["residual"]) \
        == set(abstract["residual"]) == set(want)
    for k, size in want.items():
        assert opt["residual"][k].shape == (size,)  # 1 device total
        assert opt["residual"][k].dtype == jnp.float32
        assert abstract["residual"][k].shape == (size,)


# ---------------------------------------------------------------------------
# convergence: the quadratic where plain top-k stalls and EF does not
# ---------------------------------------------------------------------------

def _quadratic_run(ef: bool, steps=200, lr=0.1):
    """2 workers, f_i(x) = ||x - c_i||^2 / 2 with c_i = [+-1, 0.25].

    True optimum x* = mean(c_i) = [0, 0.25].  Per-worker top-1 always
    picks coordinate 0 at x = 0 (|x0 -+ 1| = 1 > 0.25), and the two
    workers' coordinate-0 gradients CANCEL in the mean — so without
    error feedback the iterate never moves: an exact stall.  With EF the
    dropped coordinate-1 signal accumulates in the residual until it
    outweighs coordinate 0, gets transmitted in a burst, and the iterate
    oscillates around the optimum (constant-step EF limit-cycles; its
    Cesàro/tail average is what converges — that is what we assert).

    Returns (final iterate, tail-averaged iterate).
    """
    c = jnp.array([[1.0, 0.25], [-1.0, 0.25]])
    spec = "topk:0.5" + ("" if ef else ":noef")  # k = 1 of 2
    gs = GradSync(
        SyncConfig(scheme="dense", compress=spec),
        [], {"x": jax.ShapeDtypeStruct((2,), jnp.float32)}, 2,
        data_axis="data")
    res = gs.init_residual()
    resb = {k: jnp.zeros((2,) + v.shape, v.dtype) for k, v in res.items()}

    @jax.jit
    def sync(g, r, t):
        return jax.vmap(lambda gg, rr: gs({"x": gg}, rr, step=t),
                        axis_name="data")(g, r)

    x = jnp.zeros(2)
    tail = []
    for t in range(steps):
        g = x[None, :] - c                     # per-worker gradients [2, 2]
        synced, resb, _ = sync(g, resb, jnp.int32(t))
        x = x - lr * synced["x"][0]
        if t >= steps // 2:
            tail.append(np.asarray(x))
    return np.asarray(x), np.mean(tail, axis=0)


def test_topk_with_ef_converges_where_plain_topk_stalls():
    x_plain, avg_plain = _quadratic_run(ef=False)
    _, avg_ef = _quadratic_run(ef=True)
    opt = np.array([0.0, 0.25])
    # plain top-k: worker cancellation -> exact stall at the origin
    np.testing.assert_array_equal(x_plain, np.zeros(2))
    np.testing.assert_array_equal(avg_plain, np.zeros(2))
    # EF: the residual eventually transmits coordinate 1 -> convergence
    assert np.linalg.norm(avg_ef - opt) < 0.06, avg_ef
    assert np.linalg.norm(avg_ef - opt) < 0.2 * np.linalg.norm(
        avg_plain - opt)


# ---------------------------------------------------------------------------
# adaptive density control
# ---------------------------------------------------------------------------

def _stats_for(key, d1, dn):
    return {sparsify.DENSITY1_KEY.format(key=key): d1,
            sparsify.DENSITYN_KEY.format(key=key): dn}


def test_controller_flips_zen_to_dense_on_densification():
    ctl = DensityController({"a": 1 << 14}, {"a": "zen"}, n=2, ema=0.0)
    assert not ctl.drifted()            # no observations: keep the plan
    ctl.observe(_stats_for("a", 0.02, 0.04))
    assert not ctl.drifted()            # sparse: zen stays
    ctl.observe(_stats_for("a", 0.7, 1.0))
    drift = ctl.drifted()
    assert drift == {"a": ("zen", "dense")}
    ctl.rebase({"a": "dense"})
    assert not ctl.drifted()
    # ...and back, when the measured density thins out again
    ctl.observe(_stats_for("a", 0.01, 0.02))
    assert ctl.drifted() == {"a": ("dense", "zen")}


def test_controller_ema_smooths_single_outliers():
    ctl = DensityController({"a": 1 << 14}, {"a": "zen"}, n=2, ema=0.9)
    for _ in range(20):
        ctl.observe(_stats_for("a", 0.02, 0.04))
    ctl.observe(_stats_for("a", 0.9, 1.0))  # one outlier step
    assert not ctl.drifted()                # EMA keeps the plan stable
    for _ in range(40):
        ctl.observe(_stats_for("a", 0.9, 1.0))
    assert ctl.drifted()                    # a sustained shift flips it


def test_controller_profiles_feed_gradsync_replan():
    """The full feedback loop: measured dense-ish profile -> GradSync
    under 'auto' resolves that bucket to dense while an unmeasured one
    keeps zen — per bucket, not globally."""
    shapes = {"layers": {"w00": jax.ShapeDtypeStruct((1024,), jnp.float32),
                         "w01": jax.ShapeDtypeStruct((1024,), jnp.float32)}}
    gs0 = _make_gs("topk:0.05", shapes=shapes, n=2, bucket_bytes=4096)
    assert set(gs0.bucket_schemes().values()) == {"zen"}
    ctl = DensityController(gs0.compressed_buckets(), gs0.bucket_schemes(),
                            n=2, ema=0.0)
    key0 = next(iter(gs0.compressed_buckets()))
    ctl.observe(_stats_for(key0, 0.7, 1.0))
    assert ctl.drifted()
    gs1 = GradSync(
        SyncConfig(scheme="auto", density_budget=0.25, bucket_bytes=4096,
                   compress="topk:0.05"),
        [], shapes, 2, data_axis="data", profiles=ctl.profiles())
    schemes1 = gs1.bucket_schemes()
    assert schemes1[key0] == "dense"
    others = {k: v for k, v in schemes1.items() if k != key0}
    assert others and set(others.values()) == {"zen"}
    # bucket identity is stable across the replan: same keys, same sizes
    assert gs1.compressed_buckets() == gs0.compressed_buckets()

"""All synchronization schemes must equal the dense psum oracle, and their
traffic accounting must reproduce the paper's ordering claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import metrics, schemes


def _workers(seed, n, m, density, d=None):
    key = jax.random.PRNGKey(seed)
    masks = metrics.synth_sparse_masks(key, n, m, density)
    vals = jax.random.normal(key, (n, m) if d is None else (n, m, d))
    vals = vals * (masks if d is None else masks[..., None])
    return vals


ORACLE_TOL = 1e-4


def _check(out, oracle):
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(oracle)[None].repeat(out.shape[0], 0),
                               atol=ORACLE_TOL)


@pytest.mark.parametrize("d", [None, 8])
@pytest.mark.parametrize("n", [2, 8])
def test_all_schemes_match_oracle(n, d):
    vals = _workers(0, n, 4096, 0.05, d)
    oracle = vals.sum(0)
    cap = 1024
    out, st1 = schemes.simulate(schemes.dense_sync, vals)
    _check(out, oracle)
    out, st2 = schemes.simulate(schemes.agsparse_sync, vals, capacity=cap)
    _check(out, oracle)
    out, st3 = schemes.simulate(schemes.sparcml_sync, vals, n=n, capacity=cap)
    _check(out, oracle)
    out, st4 = schemes.simulate(schemes.sparse_ps_sync, vals, n=n,
                                cap_push=cap, cap_pull=cap)
    _check(out, oracle)
    out, st5 = schemes.simulate(schemes.omnireduce_sync, vals, n=n, block=16,
                                cap_push=cap // 16 * 2, cap_pull=cap // 16 * 2)
    _check(out, oracle)
    layout = schemes.make_zen_layout(4096, n, density_budget=0.2)
    out, st6 = schemes.simulate(schemes.zen_sync, vals, layout=layout)
    _check(out, oracle)
    for st in (st1, st2, st3, st4, st5, st6):
        assert int(np.asarray(st.overflow).sum()) == 0


def test_zen_hash_bitmap_ablation_equal():
    """Fig. 18: the hash-bitmap pull changes traffic, never values."""
    n = 4
    vals = _workers(1, n, 2048, 0.08)
    layout = schemes.make_zen_layout(2048, n, density_budget=0.2)
    out1, s1 = schemes.simulate(schemes.zen_sync, vals, layout=layout,
                                use_hash_bitmap=True)
    out2, s2 = schemes.simulate(schemes.zen_sync, vals, layout=layout,
                                use_hash_bitmap=False)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=0)


@settings(deadline=None, max_examples=8)
@given(st.integers(0, 1000))
def test_zen_exactness_property(seed):
    """Property: Zen == psum for any sparsity pattern (no information loss,
    complete aggregation) — the paper's central correctness claim."""
    n, m = 4, 1024
    vals = _workers(seed, n, m, 0.1)
    layout = schemes.make_zen_layout(m, n, density_budget=0.3, key=seed)
    out, st = schemes.simulate(schemes.zen_sync, vals, layout=layout)
    assert int(np.asarray(st.overflow).sum()) == 0
    np.testing.assert_allclose(np.asarray(out[0]),
                               np.asarray(vals.sum(0)), atol=ORACLE_TOL)


def test_zen_balanced_vs_sparse_ps_imbalanced():
    """Def. 6 comparison on a skewed tensor: Sparse PS per-partition load is
    maximally imbalanced, Zen stays near 1."""
    n, m = 8, 8192
    rng = np.random.default_rng(0)
    hot = np.zeros(m, bool)
    hot[: m // n] = rng.uniform(size=m // n) < 0.8   # all nnz in partition 0

    # sparse PS partition loads = per contiguous range
    counts_ps = hot.reshape(n, -1).sum(1)
    imb_ps = float(metrics.imbalance_ratio_pull(jnp.asarray(counts_ps)))
    layout = schemes.make_zen_layout(m, n, density_budget=0.2)
    from repro.core.hashing import hash_mod
    p = np.asarray(hash_mod(jnp.asarray(np.nonzero(hot)[0], jnp.int32),
                            layout.seeds[0], n))
    counts_zen = np.bincount(p, minlength=n)
    imb_zen = float(metrics.imbalance_ratio_pull(jnp.asarray(counts_zen)))
    assert imb_ps > 4.0           # positional split: catastrophic
    assert imb_zen < 1.25         # Zen: near-perfect balance


def test_traffic_ordering_matches_paper():
    """With overlap, Zen's wire volume beats AGsparse and dense — and dense
    beats AGsparse at high worker counts (Fig. 7 trend, executable)."""
    n, m = 8, 8192
    vals = _workers(3, n, m, 0.1)
    _, st_dense = schemes.simulate(schemes.dense_sync, vals)
    _, st_ag = schemes.simulate(schemes.agsparse_sync, vals, capacity=2048)
    layout = schemes.make_zen_layout(m, n, density_budget=0.25)
    _, st_zen = schemes.simulate(schemes.zen_sync, vals, layout=layout)
    zen_w = float(np.asarray(st_zen.sent_words).mean())
    ag_w = float(np.asarray(st_ag.sent_words).mean())
    dense_w = float(np.asarray(st_dense.sent_words).mean())
    assert zen_w < ag_w
    assert zen_w < dense_w

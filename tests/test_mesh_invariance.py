"""TP mesh-invariance contract (DESIGN.md §9).

Fast, single-device checks of the structural guarantees — shapes/keys are
pure functions of the config, vocab padding is inert, `make_ctx` rejects
non-dividing tp with a config-named error — plus a subprocess regression
test that gathered init pytrees are BITWISE identical across meshes
(the PR-4 bug: legacy non-partitionable threefry made row-sharded leaves
mesh-dependent at init).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ALL_ARCHS, get_config
from repro.models.common import (VOCAB_PAD, ParamBuilder, ShardCtx,
                                 make_ctx, path_key)
from repro.models import layers as L
from repro.models.model import assert_mesh_invariant_params, build_model

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# shapes are pure functions of the config
# ---------------------------------------------------------------------------

def test_vocab_padded_is_mesh_independent():
    cfg = get_config("qwen2-0.5b")
    vp = cfg.vocab_padded
    assert vp % VOCAB_PAD == 0 and vp >= cfg.vocab
    # property, not a function of tp: the old API was vocab_padded(tp)
    # with a max(128, tp) pad — shapes silently depended on the mesh
    assert isinstance(vp, int)


@pytest.mark.parametrize("arch", ALL_ARCHS)
@pytest.mark.parametrize("tp", [1, 2, 4])
def test_global_param_pytree_mesh_invariant(arch, tp):
    """Abstract builds only — cheap enough to sweep the whole zoo."""
    cfg = get_config(arch).reduced()
    ctx = make_ctx(cfg, tp, 1)
    assert_mesh_invariant_params(cfg, ctx)


def test_spec_tree_structure_mesh_invariant():
    """Only axis sizes may differ across meshes — the PartitionSpec TREE
    (paths and specs) must be identical (e.g. mamba2 per-head vectors
    keep P('model') even at tp=1)."""
    cfg = get_config("mamba2-370m").reduced()
    s1 = build_model(cfg, make_ctx(cfg, 1, 1)).abstract()[1]
    s4 = build_model(cfg, make_ctx(cfg, 4, 2)).abstract()[1]
    f1 = jax.tree_util.tree_flatten_with_path(
        s1, is_leaf=lambda x: isinstance(x, P))[0]
    f4 = jax.tree_util.tree_flatten_with_path(
        s4, is_leaf=lambda x: isinstance(x, P))[0]
    assert [(k, s) for k, s in f1] == [(k, s) for k, s in f4]


def test_make_ctx_rejects_bad_tp_with_config_name():
    olmoe = get_config("olmoe-1b-7b").reduced()   # 4 experts after reduce
    with pytest.raises(ValueError, match="olmoe-1b-7b.*n_experts=4"):
        make_ctx(olmoe, 8, 1)
    mamba = get_config("mamba2-370m").reduced()   # padded vocab 512
    with pytest.raises(ValueError, match="mamba2-370m.*not divisible"):
        make_ctx(mamba, 3, 1)


def test_h_pad_is_the_documented_exception():
    cfg = get_config("qwen2-0.5b")                # 14 heads
    ctx = make_ctx(cfg, 4, 1, pad_heads=True)     # 14 -> 16
    assert ctx.h_pad == 16
    # the invariance check deliberately skips the opt-in padded layout
    assert_mesh_invariant_params(cfg, ctx)


# ---------------------------------------------------------------------------
# init keys are pure functions of the leaf path
# ---------------------------------------------------------------------------

def test_param_keys_independent_of_sibling_order():
    key = jax.random.PRNGKey(7)

    def build(order):
        b = ParamBuilder(key, jnp.float32)
        for name in order:
            b.dense(name, (4, 4), P(None, None))
        return b.params

    fwd = build(["a", "b", "c"])
    rev = build(["c", "b", "a"])
    for name in "abc":
        np.testing.assert_array_equal(fwd[name], rev[name])
    # and adding a sibling must not shift an existing leaf's key
    more = ParamBuilder(key, jnp.float32)
    more.dense("z", (4, 4), P(None, None))
    more.dense("a", (4, 4), P(None, None))
    np.testing.assert_array_equal(fwd["a"], more.params["a"])


def test_stacked_layers_draw_distinct_path_keys():
    key = jax.random.PRNGKey(0)
    b = ParamBuilder(key, jnp.float32)
    b.stacked("layers", 3, lambda sb: sb.dense("w", (4,), P(None)))
    w = np.asarray(b.params["layers"]["w"])
    assert not np.allclose(w[0], w[1]) and not np.allclose(w[1], w[2])
    # leaf key is path_key(path_key(path_key(root, "layers"), i), "w")
    expect = jax.random.normal(
        path_key(path_key(path_key(key, "layers"), 1), "w"),
        (4,), jnp.float32) * 0.5
    np.testing.assert_allclose(w[1], np.asarray(expect), rtol=1e-6)


# ---------------------------------------------------------------------------
# vocab padding is inert
# ---------------------------------------------------------------------------

def _ctx1():
    return make_ctx(get_config("qwen2-0.5b").reduced(), 1, 1)


def test_padded_logits_masked_out_of_cross_entropy():
    ctx = _ctx1()
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.standard_normal((2, 3, 512)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 500, (2, 3)), jnp.int32)
    full = L.cross_entropy_sharded(logits, labels, ctx, valid_vocab=500)
    ref = L.cross_entropy_sharded(logits[..., :500], labels, ctx)
    np.testing.assert_allclose(float(full), float(ref), rtol=1e-6)


def test_padded_rows_zero_init_and_zero_grad():
    cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(),
                              vocab=500, dtype=jnp.float32)
    assert cfg.vocab_padded == 512
    ctx = make_ctx(cfg, 1, 1)
    model = build_model(cfg, ctx)
    params = model.init(jax.random.PRNGKey(0))[0]
    table = np.asarray(params["embed"]["table"])
    head = np.asarray(params["lm_head_w"])
    assert (table[500:] == 0).all(), "embedding padding rows not zero-init"
    assert (head[:, 500:] == 0).all(), "lm_head padding cols not zero-init"

    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, 500, (2, 17)), jnp.int32)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    grads = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
    g_tab = np.asarray(grads["embed"]["table"])
    g_head = np.asarray(grads["lm_head_w"])
    assert (g_tab[500:] == 0).all(), \
        "padded embedding rows leak gradient into the row-sparse sync path"
    assert (g_head[:, 500:] == 0).all(), \
        "padded lm_head columns leak gradient (logsumexp not masked)"
    assert (np.abs(g_tab[:500]).sum() > 0) and (np.abs(g_head[:, :500]).sum() > 0)


# ---------------------------------------------------------------------------
# init determinism across real meshes (subprocess: 8 host devices)
# ---------------------------------------------------------------------------

WORKER_INIT_DETERMINISM = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_mesh
    from repro.train.build import build_program

    def init(arch, mesh_shape):
        cfg = dataclasses.replace(get_config(arch).reduced(),
                                  dtype=jnp.float32)
        prog = build_program(cfg, make_mesh(mesh_shape, ("data", "model")))
        params = prog.init_params(0)
        return jax.tree_util.tree_flatten_with_path(
            jax.tree.map(lambda x: np.asarray(jax.device_get(x)), params))

    for arch in ["qwen2-0.5b", "olmoe-1b-7b", "mamba2-370m"]:
        base, bdef = init(arch, (1, 1))
        for ms in [(2, 4), (4, 2)]:
            got, gdef = init(arch, ms)
            assert bdef == gdef, (arch, ms, "pytree structure differs")
            for (kp, a), (_, b) in zip(base, got):
                path = jax.tree_util.keystr(kp)
                assert a.shape == b.shape, (arch, ms, path, a.shape, b.shape)
                if not (a == b).all():
                    d = float(np.abs(a.astype(np.float64)
                                     - b.astype(np.float64)).max())
                    raise AssertionError(
                        f"{arch} {ms} {path}: init not bitwise mesh-"
                        f"invariant (max |delta| = {d})")
        print("INIT_DETERMINISTIC", arch)
    print("ALL_OK")
""")


@pytest.mark.slow
def test_init_bitwise_deterministic_across_meshes():
    """Same seed -> bitwise-same gathered global params on (1,1), (2,4)
    and (4,2).  Guards both the path-keyed ParamBuilder and the
    threefry-partitionable requirement (repro/__init__.py)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", WORKER_INIT_DETERMINISM],
                       env=env, capture_output=True, text=True, timeout=1800)
    assert "ALL_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-4000:]


def test_shard_ctx_contract_documented():
    assert "Mesh-invariance contract" in ShardCtx.__doc__

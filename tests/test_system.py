"""End-to-end behaviour tests for the training system."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.zen import SyncConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_mesh
from repro.optim.optimizers import OptConfig
from repro.train.build import attach_train, build_program
from repro.train.steps import TrainerConfig


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1, 1), ("data", "model"))


def _run(cfg, mesh, tcfg, steps, seq=32, batch=4, seed=0,
         with_metrics=False):
    prog = build_program(cfg, mesh, tcfg)
    attach_train(prog, seq_len=seq, global_batch=batch)
    params = prog.init_params(seed)
    opt = prog.init_opt(params)
    data = iter(SyntheticLM(cfg, DataConfig(seq_len=seq, batch=batch)))
    losses = []
    for _ in range(steps):
        b = next(data)
        batch_j = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, m = prog.train_step(params, opt, batch_j)
        losses.append(float(m["loss"]))
    if with_metrics:
        sync_m = {k: float(v) for k, v in m.items() if k.startswith("sync/")}
        return losses, params, sync_m
    return losses, params


def test_loss_decreases(mesh):
    cfg = get_config("qwen2-0.5b").reduced()
    tcfg = TrainerConfig(opt=OptConfig(lr=1e-3), sync=SyncConfig())
    losses, _ = _run(cfg, mesh, tcfg, steps=12)
    assert losses[-1] < losses[0] - 0.5, losses
    assert all(np.isfinite(losses))


def test_zero1_equals_full_optimizer(mesh):
    """ZeRO-1 chunked update must be bit-compatible with the plain update
    (single device: chunking is pure reshaping)."""
    cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(),
                              dtype=jnp.float32)
    t_zero = TrainerConfig(opt=OptConfig(lr=1e-3), zero1=True)
    t_full = TrainerConfig(opt=OptConfig(lr=1e-3), zero1=False)
    l1, p1 = _run(cfg, mesh, t_zero, steps=3)
    l2, p2 = _run(cfg, mesh, t_full, steps=3)
    np.testing.assert_allclose(l1, l2, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_all_sync_schemes_end_to_end(mesh):
    """Every baseline scheme runs as the trainer's gradient synchronizer
    (the Fig. 11/12 experiment is runnable, not just modeled)."""
    cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(),
                              dtype=jnp.float32)
    ref_losses = None
    for scheme in ["dense", "zen", "agsparse", "sparse_ps", "omnireduce"]:
        tcfg = TrainerConfig(opt=OptConfig(lr=1e-3),
                             sync=SyncConfig(scheme=scheme,
                                             density_budget=0.9))
        losses, _ = _run(cfg, mesh, tcfg, steps=2)
        assert all(np.isfinite(losses)), scheme
        if ref_losses is None:
            ref_losses = losses
        else:
            # all schemes are exact at sufficient capacity -> same losses
            np.testing.assert_allclose(losses, ref_losses, rtol=1e-4,
                                       err_msg=scheme)


def test_checkpoint_roundtrip(tmp_path, mesh):
    from repro.checkpoint.io import restore, save
    cfg = get_config("qwen2-0.5b").reduced()
    prog = build_program(cfg, mesh, TrainerConfig())
    params = prog.init_params(0)
    save(tmp_path / "ckpt", {"params": params, "step": jnp.asarray(3)})
    back = restore(tmp_path / "ckpt")
    assert int(back["step"]) == 3
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_data_pipeline_determinism_and_sharding():
    cfg = get_config("qwen2-0.5b").reduced()
    dc = DataConfig(seq_len=16, batch=2, seed=7)
    a = next(iter(SyntheticLM(cfg, dc, shard=0)))
    b = next(iter(SyntheticLM(cfg, dc, shard=0)))
    c = next(iter(SyntheticLM(cfg, dc, shard=1)))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].max() < cfg.vocab


def test_auto_scheme_selection(mesh):
    """'auto' (beyond-paper): Zen for genuinely sparse leaves, dense
    fallback when the budgeted sparse volume would exceed allreduce."""
    import dataclasses as dc
    cfg = dc.replace(get_config("qwen2-0.5b").reduced(), dtype=jnp.float32)
    # low budget: embedding leaf picks zen.  0.15 provisions the measured
    # ~0.09 batch density with hash-collision headroom — "zen is exact"
    # only holds without §2 overflow, which we assert instead of assuming
    # (an under-provisioned 0.05 budget drops rows for SOME hash seeds)
    t_lo = TrainerConfig(sync=SyncConfig(scheme="auto", density_budget=0.15))
    l1, _, m1 = _run(cfg, mesh, t_lo, steps=2, with_metrics=True)
    assert m1.get("sync/buckets[zen]", 0) > 0, m1
    assert m1["sync/overflow"] == 0, m1
    # absurd budget: auto must fall back to dense (zen would be larger)
    t_hi = TrainerConfig(sync=SyncConfig(scheme="auto", density_budget=5.0))
    l2, _, m2 = _run(cfg, mesh, t_hi, steps=2, with_metrics=True)
    assert m2.get("sync/buckets[zen]", 0) == 0, m2
    t_dense = TrainerConfig(sync=SyncConfig(scheme="dense"))
    l3, _ = _run(cfg, mesh, t_dense, steps=2)
    np.testing.assert_allclose(l1, l3, rtol=1e-3)  # zen exact (no overflow)
    np.testing.assert_allclose(l2, l3, rtol=1e-6)  # dense == dense

"""§2.2 characteristics (C1–C3) + Fig. 7 cost-model orderings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costmodel as cm
from repro.core import metrics


@pytest.fixture(scope="module")
def masks():
    key = jax.random.PRNGKey(0)
    return metrics.synth_sparse_masks(key, 16, 1 << 15, 0.03)


def test_c1_partial_overlap(masks):
    """C1: sparse tensors across workers partially overlap."""
    r = float(metrics.overlap_ratio(masks[0], masks[1]))
    assert 0.05 < r < 0.95, r


def test_c2_densification(masks):
    """C2: tensors get denser after aggregation; γ^n < n."""
    g4 = float(metrics.densification_ratio(masks[:4]))
    g16 = float(metrics.densification_ratio(masks))
    assert 1.0 < g4 < 4.0
    assert g4 < g16 < 16.0


def test_c3_skewness(masks):
    """C3: non-zero locations are skewed and skew grows with partitions."""
    s8 = float(metrics.skewness_ratio(masks[0], 8))
    s64 = float(metrics.skewness_ratio(masks[0], 64))
    assert s8 > 1.5
    assert s64 > s8


def test_imbalance_defs():
    counts = jnp.asarray([[10, 10], [2, 18]])
    assert float(metrics.imbalance_ratio_push(counts)) == pytest.approx(1.8)
    assert float(metrics.imbalance_ratio_pull(
        jnp.asarray([30, 10]))) == pytest.approx(1.5)


# ---------------------------------------------------------------------------
# Fig. 7 (numerical comparison) via the analytic models
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def profile(masks):
    return cm.profile_from_masks(np.asarray(masks), block=256)


def test_fig7_agsparse_linear_in_n(profile):
    t8 = cm.agsparse(profile, 8)
    t16 = cm.agsparse(profile, 16)
    assert t16 / t8 == pytest.approx(15 / 7, rel=0.01)  # 2(n-1)dM linearity


def test_fig7_balanced_beats_everything_with_overlap(profile):
    n = 16
    t = {name: fn(profile, n) for name, fn in cm.SCHEMES.items()}
    assert t["balanced_parallelism"] <= t["sparse_ps"]
    assert t["balanced_parallelism"] < t["agsparse"]
    assert t["zen"] <= t["balanced_parallelism"] * 1.05  # bitmap pull helps
    assert t["lower_bound"] <= t["zen"]


def test_fig7_sparse_ps_skew_penalty(profile):
    """Sparse PS pays the skew factor (can exceed dense — the paper's
    observation at larger n)."""
    n = 16
    assert cm.sparse_ps(profile, n) > cm.balanced_parallelism(profile, n)
    assert cm.sparse_ps(profile, n) / cm.balanced_parallelism(profile, n) \
        == pytest.approx(profile.s(n), rel=1e-6)


def test_fig7_zen_below_dense_at_128(profile):
    """Paper: at 128 GPUs, Balanced Parallelism is ~36% below Dense while
    other schemes are at or above Dense — check the qualitative claim that
    zen stays below dense."""
    t = cm.normalized_times(profile, 128)
    assert t["zen"] < 1.0
    assert t["balanced_parallelism"] < 1.0


def test_theorem1_case1_no_overlap():
    """Thm. 1.1: with NO overlap, centralization (SparCML-style incremental
    hierarchy) matches the volume floor and parallelism has no advantage."""
    m = 1 << 14
    n = 8
    # disjoint masks -> zero overlap
    masks = np.zeros((n, m), bool)
    per = m // (2 * n)
    for i in range(n):
        masks[i, i * per:(i + 1) * per] = True
    p = cm.profile_from_masks(masks, block=256)
    # with no overlap, aggregated density = n * d and sparcml's staged sum
    # equals agsparse's volume (both must move all data to everyone)
    assert cm.sparcml(p, n) == pytest.approx(cm.agsparse(p, n), rel=0.05)
    assert cm.balanced_parallelism(p, n) >= cm.sparcml(p, n) * 0.99

"""§Perf optimizations: exactness guarantees.

  * pad-and-shard attention heads: the padded model's function AT INIT is
    exactly the spec architecture (padded head weights are zero);
  * Pallas fused flash-attention == jnp online-softmax == naive softmax.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.kernels.flash import flash_fwd
from repro.models.layers import flash_attention


def test_pad_heads_function_identical():
    """Exactness: take the UNPADDED model's params, zero-pad the head dims,
    and verify the padded model computes the identical loss."""
    from repro.models.common import make_ctx
    from repro.models.model import build_model

    cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(),
                              dtype=jnp.float32, n_heads=3, n_kv=1)
    b = next(iter(SyntheticLM(cfg, DataConfig(seq_len=16, batch=2))))
    batch = {k: jnp.asarray(v) for k, v in b.items()}

    ctx = make_ctx(cfg, 1, 1)
    model = build_model(cfg, ctx)
    params, _ = model.init(jax.random.PRNGKey(0))
    loss_ref, _ = jax.jit(model.train_loss)(params, batch)

    ctx_p = dataclasses.replace(ctx, h_pad=4, shard_heads=True)
    model_p = build_model(cfg, ctx_p)
    params_p, _ = model_p.init(jax.random.PRNGKey(0))
    # graft unpadded weights into the padded param tree (zero elsewhere)
    hd = cfg.hd
    att = params["layers"]["attn"]
    att_p = dict(params_p["layers"]["attn"])
    att_p["q_w"] = jnp.zeros_like(att_p["q_w"]).at[
        ..., : 3 * hd].set(att["q_w"])
    att_p["q_b"] = jnp.zeros_like(att_p["q_b"]).at[
        ..., : 3 * hd].set(att["q_b"])
    att_p["o_w"] = jnp.zeros_like(att_p["o_w"]).at[
        :, : 3 * hd, :].set(att["o_w"])
    for k in ("k_w", "k_b", "v_w", "v_b"):
        att_p[k] = att[k]
    params_p = dict(params_p)
    params_p["layers"] = dict(params["layers"], attn=att_p)
    for k in params:
        if k != "layers":
            params_p[k] = params[k]
    loss_pad, _ = jax.jit(model_p.train_loss)(params_p, batch)
    np.testing.assert_allclose(float(loss_pad), float(loss_ref), rtol=1e-6)


def test_pad_heads_padded_weights_zero():
    import dataclasses as dc
    from repro.models.common import make_ctx
    from repro.models.model import build_model
    cfg = dc.replace(get_config("qwen2-0.5b").reduced(), n_heads=3,
                     dtype=jnp.float32)
    ctx = make_ctx(cfg, 1, 1)
    ctx = dc.replace(ctx, h_pad=4, shard_heads=True)
    model = build_model(cfg, ctx)
    params, _ = model.init(jax.random.PRNGKey(0))
    hd = cfg.hd
    qw = params["layers"]["attn"]["q_w"]  # [L, d, H_pad*hd]
    ow = params["layers"]["attn"]["o_w"]  # [L, H_pad*hd, d]
    np.testing.assert_array_equal(np.asarray(qw[..., 3 * hd:], np.float32), 0)
    np.testing.assert_array_equal(np.asarray(ow[:, 3 * hd:, :], np.float32), 0)
    # and the function equals masking the padded head entirely: outputs of
    # padded heads hit zero o-rows => contribution is exactly zero.


@pytest.mark.parametrize("B,Sq,Sk,H,KV,hd,causal,win", [
    (2, 256, 256, 4, 2, 64, True, 0),
    (1, 128, 128, 8, 8, 32, True, 64),
    (2, 256, 256, 4, 1, 128, False, 0),
    (1, 512, 512, 2, 2, 64, True, 0),
])
def test_flash_kernel_matches_reference(B, Sq, Sk, H, KV, hd, causal, win):
    key = jax.random.PRNGKey(Sq + H)
    q = jax.random.normal(key, (B, Sq, H, hd), jnp.float32)
    k = jax.random.normal(key, (B, Sk, KV, hd), jnp.float32)
    v = jax.random.normal(key, (B, Sk, KV, hd), jnp.float32)
    got = flash_fwd(q, k, v, causal=causal, window=win, bq=128, bk=128)
    want = flash_attention(q, k, v, causal=causal, window=win, chunk=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
    # naive softmax oracle (no windowing for simplicity)
    if win == 0 and KV == H:
        qf = q.astype(jnp.float32) / np.sqrt(hd)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, k)
        if causal:
            mask = jnp.tril(jnp.ones((Sq, Sk), bool))
            s = jnp.where(mask[None, None], s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        naive = jnp.einsum("bhqk,bkhd->bqhd", w, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(naive),
                                   atol=2e-4)

"""Reproduce the paper's §2.2 analysis on REAL gradients: train the reduced
qwen2 model briefly, capture actual embedding-table gradients per step, and
measure density / overlap / densification / skewness (Defs. 3–5).

Run: PYTHONPATH=src python examples/analyze_sparsity.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.core import metrics
from repro.launch.mesh import make_mesh
from repro.models.common import make_ctx
from repro.models.model import build_model

cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(), vocab=4096)
mesh = make_mesh((1, 1), ("data", "model"))
ctx = make_ctx(cfg, 1, 1)
model = build_model(cfg, ctx)
params, _ = model.init(jax.random.PRNGKey(0))

grad_fn = jax.jit(jax.grad(lambda p, b: model.train_loss(p, b)[0]))

# emulate 8 data-parallel workers: 8 different batches, same params
masks = []
data = iter(SyntheticLM(cfg, DataConfig(seq_len=64, batch=2)))
for w in range(8):
    b = next(data)
    g = grad_fn(params, {k: jnp.asarray(v) for k, v in b.items()})
    emb = g["embed"]["table"]
    row_mask = jnp.any(emb != 0, axis=-1)
    masks.append(np.asarray(row_mask))
masks = jnp.asarray(np.stack(masks))

print("REAL embedding-gradient sparsity (reduced qwen2, vocab=4096):")
print(f"  density (per worker)  d_G   = "
      f"{float(metrics.density(masks[0])):.3%}")
print(f"  overlap ratio w0/w1  (C1)   = "
      f"{float(metrics.overlap_ratio(masks[0], masks[1])):.3f}")
print(f"  densification 8 wkr  (C2)   = "
      f"{float(metrics.densification_ratio(masks)):.2f}x")
print(f"  skewness @16 parts   (C3)   = "
      f"{float(metrics.skewness_ratio(masks[0], 16)):.2f}")
print("(Zipf token frequencies produce exactly the paper's C1-C3 regime.)")

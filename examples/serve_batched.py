"""Batched serving example: prefill a batch of prompts, then decode with
the sequence-sharded KV cache (greedy).

Run: PYTHONPATH=src python examples/serve_batched.py [--arch mamba2-370m]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_mesh
from repro.train.build import attach_serve, build_program

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen2-0.5b", choices=ALL_ARCHS)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=32)
ap.add_argument("--gen", type=int, default=48)
args = ap.parse_args()

cfg = get_config(args.arch).reduced()
mesh = make_mesh((1, 1), ("data", "model"))
prog = build_program(cfg, mesh)

# --- prefill -------------------------------------------------------------
attach_serve(prog, seq_len=args.prompt_len, global_batch=args.batch,
             mode="prefill")
params = prog.init_params(0)
b = next(iter(SyntheticLM(cfg, DataConfig(seq_len=args.prompt_len,
                                          batch=args.batch))))
prompt = {k: jnp.asarray(v) for k, v in b.items() if k != "labels"}
prompt["tokens"] = prompt["tokens"][:, : args.prompt_len]
t0 = time.time()
logits, cache = prog.prefill_step(params, prompt)
jax.block_until_ready(logits)
print(f"prefill: batch={args.batch} len={args.prompt_len} "
      f"{(time.time() - t0) * 1e3:.0f}ms")

# --- decode ---------------------------------------------------------------
attach_serve(prog, seq_len=args.prompt_len + args.gen,
             global_batch=args.batch, mode="decode")
# re-home the prefill cache into the decode-length cache
dec_cache = prog.fresh_cache()
if "cross" in dec_cache and "cross" in cache:
    dec_cache["cross"] = cache["cross"]

tok = prompt["tokens"][:, -1:]
out = []
t0 = time.time()
# replay prompt (simple re-home; a production server would carry the
# prefill cache over directly when lengths match)
for i in range(args.prompt_len):
    _, _, dec_cache = prog.decode_step(params, dec_cache,
                                       prompt["tokens"][:, i:i + 1])
for i in range(args.gen):
    tok, lmax, dec_cache = prog.decode_step(params, dec_cache, tok)
    out.append(np.asarray(tok)[:, 0])
jax.block_until_ready(tok)
dt = time.time() - t0
total = args.batch * (args.prompt_len + args.gen)
gen = np.stack(out, 1)
print(f"decode: generated {args.gen} tokens x {args.batch} seqs "
      f"in {dt:.2f}s ({total / dt:,.0f} tok/s incl. replay)")
print("sample token ids:", gen[0][:16])
assert np.isfinite(np.asarray(lmax, np.float32)).all()

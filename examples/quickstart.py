"""Quickstart: Zen sparse gradient synchronization in 60 seconds.

1. Build skewed sparse gradients on 8 simulated workers.
2. Synchronize them with Zen (hierarchical hashing + hash bitmap).
3. Verify exactness vs dense allreduce and compare wire volume.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics, schemes

N_WORKERS = 8
TENSOR = 1 << 16          # embedding-gradient rows
DENSITY = 0.03

key = jax.random.PRNGKey(0)
masks = metrics.synth_sparse_masks(key, N_WORKERS, TENSOR, DENSITY)
grads = jax.random.normal(key, (N_WORKERS, TENSOR)) * masks

print(f"workers={N_WORKERS} tensor={TENSOR} "
      f"density={float(metrics.density(masks[0])):.3%} "
      f"skew(16)={float(metrics.skewness_ratio(masks[0], 16)):.1f} "
      f"densification(8)={float(metrics.densification_ratio(masks)):.2f}")

# --- Zen ---------------------------------------------------------------
layout = schemes.make_zen_layout(TENSOR, N_WORKERS, density_budget=0.08)
zen_out, zen_stats = schemes.simulate(schemes.zen_sync, grads, layout=layout)

# --- dense oracle -------------------------------------------------------
dense_out, dense_stats = schemes.simulate(schemes.dense_sync, grads)

err = float(jnp.max(jnp.abs(zen_out - dense_out)))
zen_words = float(np.asarray(zen_stats.sent_words).mean())
dense_words = float(np.asarray(dense_stats.sent_words).mean())
print(f"max |zen - allreduce| = {err:.2e}  (no information loss)")
print(f"wire volume: zen={zen_words:,.0f} words, "
      f"allreduce={dense_words:,.0f} words "
      f"-> {dense_words / zen_words:.1f}x less traffic")
assert err < 1e-5

"""Quickstart: Zen sparse gradient synchronization in 60 seconds.

1. Build skewed sparse gradients on 8 simulated workers.
2. Synchronize them with Zen (hierarchical hashing + hash bitmap).
3. Verify exactness vs dense allreduce and compare wire volume.
4. Rerun under FULL skew (one worker holds every nonzero) with the
   balanced Ok-Topk-style scheme (``--sync balanced`` on
   ``launch/train.py`` / ``launch/dryrun.py``): its histogram
   rebalance bounds every worker's buffers by nnz_total/n + one-bin
   slack — no nnz_max term — where agsparse must provision the whole
   total (DESIGN.md §12).
5. Induce sparsity on DENSE gradients with error-feedback top-k
   (``--compress``) and watch 'auto' route them through Zen.

Dense models have nothing naturally sparse to ship — ``--compress
topk:0.01`` (on ``launch/train.py`` / ``launch/dryrun.py``, or
``SyncConfig(compress="topk:0.01")`` in code) keeps only the top 1% of
each fused gradient bucket and carries the rest in an error-feedback
residual inside optimizer state, so nothing is lost, only deferred.
The compressed buckets then ride the same sparse schemes as embedding
tables — under ``scheme='auto'`` the cost model picks zen vs dense per
bucket from the *measured* post-compression density (``--replan-every``
closes that feedback loop during training).  Append ``:noef`` to see
why the residual matters (benchmarks/fig14_accuracy.py quantifies it).

The volume model prices the wire only; ``--calib-file PATH`` (on
``launch/train.py`` / ``launch/dryrun.py``) additionally charges each
scheme its *measured* encode time on this machine — run
``PYTHONPATH=src python -m repro.core.costmodel --calib-file calib.json``
once to produce the table (train.py auto-calibrates a missing file),
and 'auto' will pick dense wherever encode cost eats the wire win
(DESIGN.md §11).  The table also carries a directly measured
``commit_us`` — the server-side aggregate+re-encode, which on the
pallas backend runs as one fused push megakernel and one fused
pull-decode megakernel (``--no-fused-commit`` on ``launch/train.py``
switches back to the pre-fusion dispatch chain, bit-identically;
DESIGN.md §14).

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics, schemes

N_WORKERS = 8
TENSOR = 1 << 16          # embedding-gradient rows
DENSITY = 0.03

key = jax.random.PRNGKey(0)
masks = metrics.synth_sparse_masks(key, N_WORKERS, TENSOR, DENSITY)
grads = jax.random.normal(key, (N_WORKERS, TENSOR)) * masks

print(f"workers={N_WORKERS} tensor={TENSOR} "
      f"density={float(metrics.density(masks[0])):.3%} "
      f"skew(16)={float(metrics.skewness_ratio(masks[0], 16)):.1f} "
      f"densification(8)={float(metrics.densification_ratio(masks)):.2f}")

# --- Zen ---------------------------------------------------------------
layout = schemes.make_zen_layout(TENSOR, N_WORKERS, density_budget=0.08)
zen_out, zen_stats = schemes.simulate(schemes.zen_sync, grads, layout=layout)

# --- dense oracle -------------------------------------------------------
dense_out, dense_stats = schemes.simulate(schemes.dense_sync, grads)

err = float(jnp.max(jnp.abs(zen_out - dense_out)))
zen_words = float(np.asarray(zen_stats.sent_words).mean())
dense_words = float(np.asarray(dense_stats.sent_words).mean())
print(f"max |zen - allreduce| = {err:.2e}  (no information loss)")
print(f"wire volume: zen={zen_words:,.0f} words, "
      f"allreduce={dense_words:,.0f} words "
      f"-> {dense_words / zen_words:.1f}x less traffic")
assert err < 1e-5

# --- balanced under full skew (--sync balanced) -------------------------
from repro.core.registry import BALANCED_BINS  # noqa: E402

nnz_total = int(TENSOR * DENSITY)
skewed = np.zeros((N_WORKERS, TENSOR), np.float32)
hot = np.random.default_rng(0).choice(TENSOR, nnz_total, replace=False)
skewed[0, hot] = 1.0                      # ONE worker holds every nonzero
skewed = jnp.asarray(skewed)
bal_cap = nnz_total // N_WORKERS \
    + min(nnz_total, N_WORKERS * (TENSOR // BALANCED_BINS))
bal_out, bal_stats = schemes.simulate(
    schemes.balanced_sync, skewed, n=N_WORKERS,
    cap_push=bal_cap, cap_pull=bal_cap)
ags_out, ags_stats = schemes.simulate(
    schemes.agsparse_sync, skewed, capacity=nnz_total)  # needs nnz_max!
assert int(np.asarray(bal_stats.overflow).sum()) == 0
np.testing.assert_allclose(np.asarray(bal_out),
                           np.asarray(skewed.sum(0))[None]
                           .repeat(N_WORKERS, 0), atol=1e-5)
bal_max = float(np.asarray(bal_stats.sent_words).max())
ags_max = float(np.asarray(ags_stats.sent_words).max())
print(f"full skew, {nnz_total} nonzeros all on worker 0: "
      f"balanced bottleneck={bal_max:,.0f} words "
      f"(buffers {bal_cap}/worker, skew-independent) vs "
      f"agsparse={ags_max:,.0f} (capacity must be nnz_max={nnz_total}) "
      f"-> {ags_max / bal_max:.1f}x less at the bottleneck")

# --- induced sparsity: EF top-k on a DENSE gradient tree ----------------
from repro.core.zen import GradSync, SyncConfig  # noqa: E402

shapes = {"mlp": {f"w{i}": jax.ShapeDtypeStruct((4096,), jnp.float32)
                  for i in range(8)}}
dense_grads = {"mlp": {f"w{i}": jax.random.normal(
    jax.random.fold_in(key, i), (N_WORKERS, 4096)) for i in range(8)}}
gs = GradSync(SyncConfig(scheme="auto", compress="topk:0.01",
                         bucket_bytes=1 << 14),
              [], shapes, N_WORKERS, data_axis="data")
resid = {k: jnp.zeros((N_WORKERS, *r.shape), r.dtype)
         for k, r in gs.init_residual().items()}
_, resid, stats = jax.vmap(lambda g, r: gs(g, r), axis_name="data")(
    dense_grads, resid)
wire = float(np.asarray(stats["sync/sparse_sent_words"]).mean()) \
    + float(np.asarray(stats["sync/dense_words"]).mean())
ring = 2 * (N_WORKERS - 1) / N_WORKERS * 8 * 4096
print(f"EF top-k 1% on dense grads: schemes={gs.bucket_schemes()} "
      f"wire={wire:,.0f} vs allreduce={ring:,.0f} words "
      f"({wire / ring:.1%}); dropped mass held in "
      f"{len(resid)} residual buckets")
assert wire < 0.10 * ring

"""End-to-end driver: train a ~100M-param qwen2-family model for a few
hundred steps with Zen gradient synchronization, checkpointing, and a
throughput report.

This is the (b) deliverable's end-to-end example.  It runs on one CPU
device (mesh 1x1); on a real pod, pass a bigger mesh via repro.launch.train.

Run: PYTHONPATH=src python examples/train_e2e.py [--steps 200]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.io import restore, save
from repro.configs import get_config
from repro.core.zen import SyncConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_mesh
from repro.optim.optimizers import OptConfig
from repro.train.build import attach_train, build_program
from repro.train.steps import TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--ckpt", default="/tmp/zen_e2e_ckpt")
args = ap.parse_args()

# ~100M params: qwen2-0.5b geometry, shrunk to 8 layers / d512 but with the
# full 151936-token vocabulary so the embedding grads are genuinely sparse.
cfg = dataclasses.replace(
    get_config("qwen2-0.5b"),
    n_layers=8, d_model=512, n_heads=8, n_kv=2, head_dim=64, d_ff=1536)

mesh = make_mesh((1, 1), ("data", "model"))
tcfg = TrainerConfig(
    opt=OptConfig(lr=3e-4, grad_clip=1.0),
    sync=SyncConfig(scheme="zen", density_budget=0.25),
    zero1=True)
prog = build_program(cfg, mesh, tcfg)

SEQ, BATCH = 256, 8
attach_train(prog, seq_len=SEQ, global_batch=BATCH)
params = prog.init_params(0)
opt = prog.init_opt(params)
n_params = sum(p.size for p in jax.tree.leaves(params))
print(f"model: {cfg.name}-100m  params={n_params / 1e6:.1f}M  "
      f"vocab={cfg.vocab}")

data = iter(SyntheticLM(cfg, DataConfig(seq_len=SEQ, batch=BATCH)))
t0, losses = time.time(), []
for step in range(args.steps):
    b = next(data)
    batch = {k: jnp.asarray(v) for k, v in b.items()}
    params, opt, m = prog.train_step(params, opt, batch)
    losses.append(float(m["loss"]))
    if step % 20 == 0:
        toks = BATCH * SEQ * (step + 1)
        print(f"step {step:4d}  loss={losses[-1]:.4f}  "
              f"tok/s={toks / (time.time() - t0):,.0f}  "
              f"zen_words={float(m['sync/sparse_sent_words']):,.0f}")

save(args.ckpt, {"params": params, "step": jnp.asarray(args.steps)})
back = restore(args.ckpt)
assert int(back["step"]) == args.steps
print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f}); "
      f"checkpoint verified at {args.ckpt}")
assert losses[-1] < losses[0]

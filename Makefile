# Dev entry points. Everything runs on CPU (pallas kernels in interpret
# mode); PYTHONPATH=src is the only environment the repo needs.

PY := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python

.PHONY: test bench-smoke ci

test:
	$(PY) -m pytest -x -q

# fast benchmark smoke: Table 1 + Fig. 7 analytics + the zen_sync
# micro-benchmark that refreshes BENCH_sync.json
bench-smoke:
	$(PY) -m benchmarks.run --json BENCH_run.json tab1_stats fig7_schemes micro_sync

ci: test bench-smoke

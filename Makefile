# Dev entry points. Everything runs on CPU (pallas kernels in interpret
# mode); PYTHONPATH=src is the only environment the repo needs.

PY := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python

# files held to `ruff format` (new code; the seed tree predates the
# formatter and reflowing it would bury real diffs)
FORMATTED := src/repro/train/schedule.py benchmarks/check_regression.py

.PHONY: test lint bench-smoke bench-gate ci

test:
	$(PY) -m pytest -x -q

# ruff check uses the default E4/E7/E9/F rule set (ruff.toml); the CI lint
# job installs ruff — locally we skip with a note if it is absent so
# `make ci` stays runnable on the minimal image.
lint:
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src tests benchmarks examples && \
		ruff format --check $(FORMATTED); \
	else \
		echo "ruff not installed; skipping lint (CI runs it)"; \
	fi

# fast benchmark smoke: Table 1 + Fig. 7 analytics + the zen_sync
# micro-benchmark that refreshes BENCH_sync.json
bench-smoke:
	$(PY) -m benchmarks.run --json BENCH_run.json tab1_stats fig7_schemes micro_sync

# CI perf gate: replay micro_sync in smoke mode and diff stage timings /
# wire volumes against the committed baseline (±30%, BENCH_TOLERANCE to
# override)
bench-gate:
	$(PY) -m benchmarks.micro_sync --smoke --json BENCH_smoke.json
	$(PY) -m benchmarks.check_regression BENCH_sync.json BENCH_smoke.json

ci: lint test bench-smoke

# Dev entry points. Everything runs on CPU (pallas kernels in interpret
# mode); PYTHONPATH=src is the only environment the repo needs.

PY := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python

# files held to `ruff format` (new code; the seed tree predates the
# formatter and reflowing it would bury real diffs)
FORMATTED := src/repro/train/schedule.py benchmarks/check_regression.py

.PHONY: test test-crossmesh test-hier lint check-bytecode check-registry check-ast check-hlo bench-smoke bench-gate ci

test:
	$(PY) -m pytest -x -q

# full cross-mesh parity matrix (DESIGN.md §9): {attention, MoE, SSM} x
# meshes {(1,1),(1,8)/(8,1),(2,4),(4,2)} x schemes {dense, zen, auto,
# topk-EF} on 8 host devices.  Tier-1 always runs the fast 2-config
# subset (test_cross_mesh_consistency); the CI multidevice job runs this
# full matrix.  The workers force their own
# --xla_force_host_platform_device_count=8.
test-crossmesh:
	REPRO_CROSSMESH=full $(PY) -m pytest -x -q \
		tests/test_multidevice.py -k "cross_mesh_parity_matrix"

# full hierarchical-topology invariance matrix (DESIGN.md §10): meshes
# {(1,1),(8,1),(2,4)} x node_size {1,2,4} x {dense, zen, auto} on 8 host
# devices, non-dividing combos asserted to fail fast.  Tier-1 always runs
# the fast subset (test_hierarchical_sync_on_mesh); the CI multidevice
# job's hierarchical leg runs this full matrix.
test-hier:
	REPRO_HIER=full $(PY) -m pytest -x -q \
		tests/test_multidevice.py -k "hierarchical_parity_matrix"

# fail if any python bytecode is tracked by git (a PR-2 leak committed 84
# __pycache__ files; .gitignore prevents new ones, this gate enforces it)
check-bytecode:
	@if git ls-files | grep -E '\.pyc$$|__pycache__'; then \
		echo "ERROR: bytecode files are tracked by git (see above)"; \
		exit 1; \
	else \
		echo "no tracked bytecode"; \
	fi

# ruff check uses the default E4/E7/E9/F rule set (ruff.toml); the CI lint
# job installs ruff — locally we skip with a note if it is absent so
# `make ci` stays runnable on the minimal image.
lint:
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src tests benchmarks examples && \
		ruff format --check $(FORMATTED); \
	else \
		echo "ruff not installed; skipping lint (CI runs it)"; \
	fi

# registry coverage (DESIGN.md §12): every registered scheme must carry
# a volume and a rounds function that evaluate sanely, and every
# executable scheme must appear in a tier-1 test — a scheme cannot be
# added without a parity test riding along.  Folded into the zenlint
# driver (DESIGN.md §13) so all three static gates share one entry point.
check-registry:
	$(PY) -m repro.analysis.lint --registry-only

# zenlint AST layer (DESIGN.md §13): no raw collectives outside
# schemes.py/kernels/, no scheme-name dispatch chains, no hardcoded CLI
# scheme choices
check-ast:
	$(PY) -m repro.analysis.lint --ast-only

# zenlint HLO sweep (DESIGN.md §13): lower every executable scheme
# (flat + hier, n in {2,8}) plus the run_schedule pipeline on the host
# mesh and certify the R1-R5 paper invariants (sort-free, wire-exact,
# no f64, fences intact, no dynamic fallbacks)
check-hlo:
	$(PY) -m repro.analysis.lint --hlo-only

# fast benchmark smoke: Table 1 + Fig. 7 analytics + the zen_sync
# micro-benchmark that refreshes BENCH_sync.json
bench-smoke:
	$(PY) -m benchmarks.run --json BENCH_run.json tab1_stats fig7_schemes micro_sync

# CI perf gate: replay micro_sync in smoke mode and diff stage timings /
# wire volumes against the committed baseline (±30%, BENCH_TOLERANCE to
# override)
bench-gate:
	$(PY) -m benchmarks.micro_sync --smoke --json BENCH_smoke.json
	$(PY) -m benchmarks.check_regression BENCH_sync.json BENCH_smoke.json

# refresh the committed perf baseline: a full run for trajectory coverage,
# then the gate-shared entries re-measured by the SAME --smoke procedure CI
# replays (full-mode runs warm caches differently — observed up to 1.4x
# full-vs-smoke bias on sparse_ps — so like must be compared with like)
bench-baseline:
	$(PY) -m benchmarks.micro_sync BENCH_sync.json
	$(PY) -m benchmarks.micro_sync --smoke --json BENCH_smoke.json
	$(PY) -m benchmarks.merge_baseline BENCH_sync.json BENCH_smoke.json

ci: lint check-bytecode check-ast check-registry test check-hlo bench-smoke
